//! Lowering a validated flat tape to native execution — the compiled-tape
//! backend.
//!
//! `transform` produces a `FlatProgram` whose statements reference only
//! offsets/content arrays and f64 slots; `flat` and `tape` *interpret* that
//! program (tree walk and postfix VM respectively), paying per-node or
//! per-op dispatch in the hottest loop of the system. This module instead
//! **compiles** the program once into a graph of monomorphic Rust closures:
//!
//!   * every expression node becomes one direct call into a closure that
//!     captures its children by value — no opcode decode, no operand stack,
//!     no `Box<CExpr>` pointer chasing per evaluation;
//!   * constant subtrees are folded at lower time;
//!   * builtin calls resolve to `fn(f64) -> f64` pointers at lower time, so
//!     `sqrt`/`cosh`/`cos` in the pair loop are direct math calls;
//!   * the fused single-list special case runs as one flat loop over the
//!     content arrays, exactly the shape of `engine::columnar_exec`;
//!   * fused bodies additionally lower to a **chunked batch kernel**
//!     (`BExpr`): items are processed in fixed-size batches of `CHUNK`
//!     through flat `f64` buffers with branch-free bin accumulation into a
//!     scratch histogram, so rustc/LLVM can autovectorize the arithmetic —
//!     the paper's "minimal for loop" rung reached from compiled query
//!     source. `if` cuts lower to **0/1 masks** (nested cuts conjoin,
//!     `else` branches negate; the mask selects the fill's value and
//!     weight instead of branching), and bodies with several `Fill`
//!     statements run as **one shared batch pass**: every distinct
//!     mask/value/weight expression is interned into a shared buffer table
//!     evaluated once per chunk, so a cut or weight common to several fill
//!     sites is computed once.
//!
//! The full pipeline this module sits in — and every stage's defining file
//! — is documented in `docs/ARCHITECTURE.md`; the source language itself in
//! `docs/QUERY_LANGUAGE.md`.
//!
//! Execution is **range-aware**: `run_range` evaluates any event window of
//! a partition through a zero-copy `ColumnRange` view, which is what the
//! morsel-driven scheduler (`run_parallel`) uses to spread one partition
//! across every core: cache-sized morsels are pulled from a shared atomic
//! counter by a scoped thread pool and the per-morsel histograms are merged
//! in morsel order, so results are deterministic for a fixed morsel size.
//!
//! Execution is also **index-aware**: when a partition carries a zone map
//! (`crate::index`), `run_parallel_indexed`/`run_indexed` evaluate the
//! program's cut predicate (`super::predicate`) against the per-chunk
//! statistics and classify every `CHUNK`-aligned batch as skip (provably
//! empty — no work at all), take-all (cut provably passes everywhere — the
//! mask buffers are dropped and the unmasked kernel runs) or scan. Both
//! short cuts are bit-identical to the full scan: a skipped chunk's items
//! would have contributed exact `+0.0`s, and an always-true mask selects
//! every value unchanged. [`IndexedRun`] reports what happened.
//!
//! The execution state is a slot vector plus borrowed column slices: no
//! allocation happens inside the event loop. This is the in-repo analogue
//! of the paper handing transformed code to Numba/Clang — same semantics
//! (cross-checked against `flat`, `tape` and the object interpreter by the
//! property suite), a fraction of the interpretive overhead.
//!
//! `fingerprint` hashes the canonical transformed program (slot-numbered,
//! name- and whitespace-free), which is what the server's result cache keys
//! on: two textually different sources that transform to the same tape hit
//! the same cache line.

use super::ast::{BinOp, CmpOp};
use super::predicate::{self, CutPredicate, ZoneDecision};
use super::transform::{CExpr, CStmt, FlatProgram};
use crate::columnar::arrays::{ColumnRange, ColumnSet};
use crate::hist::H1;
use crate::index::ZoneMap;
use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Batch width of the chunked kernel. 1024 f64 lanes = 8 KiB per buffer:
/// big enough to amortize loop overhead and keep LLVM's vectorizer happy,
/// small enough that expr + weight + temporaries stay L1/L2-resident.
pub const CHUNK: usize = 1024;

/// Deepest batch expression the chunked kernel will take. `beval` keeps one
/// `CHUNK`-sized stack buffer per binary node on the recursion path, so this
/// bounds kernel stack use (~8 KiB × depth). Exceeding it is the **only**
/// fused shape that still runs the scalar closure loop: cut bodies and
/// multi-`Fill` bodies batch (mask-and-fill), so a fused body falls back
/// only when some mask/value/weight tree is pathologically deep.
const MAX_BATCH_DEPTH: usize = 24;

/// Default morsel size for `run_parallel`, in events. Physics partitions
/// run a few hundred bytes per event across the touched branches, so 8k
/// events keeps a morsel's working set around the L2 cache while leaving
/// plenty of morsels for work stealing.
pub const DEFAULT_MORSEL_EVENTS: usize = 8192;

/// Execution context: column views resolved once per partition, plus the
/// mutable slot file. Expression closures only read (`&Ctx`); statement
/// closures mutate slots (`&mut Ctx`).
pub struct Ctx<'a> {
    item_cols: Vec<&'a [f32]>,
    event_cols: Vec<&'a [f32]>,
    offsets: Vec<&'a [i64]>,
    slots: Vec<f64>,
    event: usize,
    /// One past the last event of the window this context executes; the
    /// `__list_total` builtin reads offsets at this index so fused loops
    /// stay correct on sub-partition (morsel) views.
    ev_hi: usize,
    /// Sticky out-of-bounds flag: loads report OOB here (returning 0.0)
    /// instead of threading `Result` through every closure call.
    oob: Cell<bool>,
}

type ExprFn = Box<dyn Fn(&Ctx) -> f64 + Send + Sync>;
type StmtFn = Box<dyn Fn(&mut Ctx, &mut H1) + Send + Sync>;

/// The fused single-list loop, decomposed so it can run over any item
/// range: `for k in offsets[list][ev_lo] .. offsets[list][ev_hi]`.
struct FusedLoop {
    /// Which list's offsets bound the flat loop.
    list: usize,
    /// Slot holding the current global item index.
    slot: usize,
    /// Scalar fallback: the loop body as compiled closures.
    body: Vec<StmtFn>,
    /// Chunked batch kernel, when every body expression is batchable.
    chunked: Option<ChunkedBody>,
}

/// A lowered program: closure graphs for the statement tree, ready to bind
/// to any partition with a matching schema.
pub struct CompiledProgram {
    pub item_cols: Vec<String>,
    pub event_cols: Vec<String>,
    pub lists: Vec<String>,
    pub n_slots: usize,
    body: Vec<StmtFn>,
    fused: Option<FusedLoop>,
    /// Cut predicate of the fused body, when it has the analyzable shape —
    /// what zone-map partition/chunk classification evaluates.
    predicate: Option<CutPredicate>,
    /// Canonical hash of the transformed program this was lowered from.
    pub fingerprint: u64,
}

impl CompiledProgram {
    /// Does this program run as one fused flat loop over a single list?
    pub fn is_fused(&self) -> bool {
        self.fused.is_some()
    }

    /// Does the fused loop lower to the chunked SIMD-friendly kernel
    /// (the mask-and-fill batch pass)?
    pub fn has_chunked_kernel(&self) -> bool {
        self.fused.as_ref().is_some_and(|f| f.chunked.is_some())
    }

    /// Shape of the chunked kernel this program lowered to, if any —
    /// observability for tests, benches and server stats.
    pub fn chunked_info(&self) -> Option<ChunkedInfo> {
        let ck = self.fused.as_ref()?.chunked.as_ref()?;
        Some(ChunkedInfo {
            fills: ck.fills.len(),
            masked_fills: ck.fills.iter().filter(|f| f.mask.is_some()).count(),
            buffers: ck.bufs.len(),
        })
    }

    /// The cut predicate zone-map pruning evaluates, if the program has
    /// the analyzable fused shape.
    pub fn predicate(&self) -> Option<&CutPredicate> {
        self.predicate.as_ref()
    }

    /// Can zone maps prune for this program at all?
    pub fn is_prunable(&self) -> bool {
        self.predicate.is_some()
    }
}

/// Lowering report for the chunked kernel: how many fill sites batched,
/// how many are cut-guarded, and how large the shared buffer table is.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChunkedInfo {
    /// Batch-lowered fill sites.
    pub fills: usize,
    /// Fill sites guarded by a cut mask.
    pub masked_fills: usize,
    /// Distinct batch buffers evaluated per chunk — the shared-subexpression
    /// table (a mask/value/weight appearing at several sites counts once).
    pub buffers: usize,
}

/// Intra-partition parallelism: how many morsel threads one `run_parallel`
/// call may use, and how many events each morsel spans.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ParallelCfg {
    /// Worker threads for one partition run. 1 = sequential (the default:
    /// cluster workers already parallelize across partitions); 0 = use all
    /// available cores.
    pub threads: usize,
    /// Events per morsel; 0 = `DEFAULT_MORSEL_EVENTS`.
    pub morsel_events: usize,
}

impl Default for ParallelCfg {
    fn default() -> ParallelCfg {
        ParallelCfg {
            threads: 1,
            morsel_events: 0,
        }
    }
}

impl ParallelCfg {
    /// All cores, default morsel size.
    pub fn auto() -> ParallelCfg {
        ParallelCfg {
            threads: 0,
            morsel_events: 0,
        }
    }

    /// The thread count after resolving 0 = all available cores.
    pub fn resolved_threads(&self) -> usize {
        match self.threads {
            0 => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            n => n,
        }
    }

    /// The morsel size after resolving 0 = default.
    pub fn resolved_morsel_events(&self) -> usize {
        match self.morsel_events {
            0 => DEFAULT_MORSEL_EVENTS,
            n => n,
        }
    }
}

/// What zone-map pruning did during one (indexed) run: how many
/// `CHUNK`-aligned zone chunks were skipped outright, ran unmasked because
/// the cut was provably true, or ran the normal masked scan. Each chunk is
/// counted once per run even when morsel windows split it (the window
/// containing the chunk's start reports it). All zeros when no zone map
/// was supplied or the program is not prunable.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct IndexedRun {
    /// Chunks proven empty by the predicate — not touched at all.
    pub chunks_skipped: u64,
    /// Chunks where the cut is provably true — mask dropped.
    pub chunks_take_all: u64,
    /// Chunks the statistics could not decide — masked scan.
    pub chunks_scanned: u64,
}

impl IndexedRun {
    /// Accumulate another report (morsel merges, backend counters).
    pub fn absorb(&mut self, o: &IndexedRun) {
        self.chunks_skipped += o.chunks_skipped;
        self.chunks_take_all += o.chunks_take_all;
        self.chunks_scanned += o.chunks_scanned;
    }

    /// Chunks the index decided without a scan.
    pub fn chunks_pruned(&self) -> u64 {
        self.chunks_skipped + self.chunks_take_all
    }
}

/// Per-partition chunk classification, precomputed once per run from the
/// program's predicate and the partition's zone map.
struct ChunkPlan {
    /// Decision per `CHUNK`-aligned item chunk of the fused list.
    decisions: Vec<ZoneDecision>,
}

/// Build the chunk plan for one partition, when everything lines up: the
/// program is prunable, runs the chunked kernel, and the zone map's grid
/// matches the kernel's batch width.
fn chunk_plan(prog: &CompiledProgram, zm: &ZoneMap) -> Option<ChunkPlan> {
    if zm.chunk_items != CHUNK {
        return None;
    }
    let fused = prog.fused.as_ref()?;
    fused.chunked.as_ref()?;
    let decisions = prog.predicate.as_ref()?.classify_chunks(zm)?;
    Some(ChunkPlan { decisions })
}

/// FNV-1a, used for program fingerprints and cache keys.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Canonical serialization of a transformed program. Variable names and
/// formatting are already gone after `transform` (slots + column indices
/// only), so two sources that differ only in naming/whitespace serialize
/// identically. Collision-free (unlike a digest), so it is safe to use as
/// a cache key for untrusted query source.
pub fn canonical(prog: &FlatProgram) -> String {
    format!(
        "items={:?};events={:?};lists={:?};slots={};body={:?}",
        prog.item_cols, prog.event_cols, prog.lists, prog.n_slots, prog.body
    )
}

/// Canonical hash of a transformed program (digest of `canonical`; fine
/// for fingerprint display/telemetry — use `canonical` itself for keys).
pub fn fingerprint(prog: &FlatProgram) -> u64 {
    fnv1a(canonical(prog).as_bytes())
}

/// Lower a transformed program into a compiled closure graph.
pub fn lower(prog: &FlatProgram) -> Result<CompiledProgram, String> {
    Ok(CompiledProgram {
        item_cols: prog.item_cols.clone(),
        event_cols: prog.event_cols.clone(),
        lists: prog.lists.clone(),
        n_slots: prog.n_slots,
        body: compile_block(&prog.body)?,
        fused: match &prog.fused {
            Some(b) => compile_fused(b)?,
            None => None,
        },
        predicate: predicate::extract(prog),
        fingerprint: fingerprint(prog),
    })
}

/// Resolve the program's column bindings against one partition and build a
/// fresh execution context for the event window `[ev_lo, ev_hi)`.
fn bind<'a>(prog: &CompiledProgram, view: &ColumnRange<'a>) -> Result<Ctx<'a>, String> {
    let cs = view.cs;
    let mut item_cols = Vec::with_capacity(prog.item_cols.len());
    for path in &prog.item_cols {
        item_cols.push(
            cs.leaf(path)
                .ok_or_else(|| format!("no leaf '{path}'"))?
                .as_f32()
                .ok_or_else(|| format!("leaf '{path}' is not f32"))?,
        );
    }
    let mut event_cols = Vec::with_capacity(prog.event_cols.len());
    for path in &prog.event_cols {
        event_cols.push(
            cs.leaf(path)
                .ok_or_else(|| format!("no leaf '{path}'"))?
                .as_f32()
                .ok_or_else(|| format!("leaf '{path}' is not f32"))?,
        );
    }
    let mut offsets = Vec::with_capacity(prog.lists.len());
    for path in &prog.lists {
        let off = cs
            .offsets_of(path)
            .ok_or_else(|| format!("no list '{path}'"))?;
        // Validate once so the per-event loop can index offsets directly.
        if off.len() != cs.n_events + 1 {
            return Err(format!(
                "offsets '{path}' length {} != n_events+1 {}",
                off.len(),
                cs.n_events + 1
            ));
        }
        offsets.push(off);
    }
    Ok(Ctx {
        item_cols,
        event_cols,
        offsets,
        slots: vec![0.0; prog.n_slots],
        event: view.ev_lo,
        ev_hi: view.ev_hi,
        oob: Cell::new(false),
    })
}

/// Run a compiled program over one whole partition, accumulating into
/// `hist`.
pub fn run(prog: &CompiledProgram, cs: &ColumnSet, hist: &mut H1) -> Result<(), String> {
    run_range(prog, &cs.range(0, cs.n_events), hist)
}

/// Run one whole partition with zone-map chunk skipping. Equals `run`
/// bit-for-bit (a skipped chunk's items would have contributed exact
/// `+0.0`s; a take-all chunk runs the same arithmetic minus the mask);
/// returns what the index decided.
pub fn run_indexed(
    prog: &CompiledProgram,
    cs: &ColumnSet,
    zm: Option<&ZoneMap>,
    hist: &mut H1,
) -> Result<IndexedRun, String> {
    let plan = zm.and_then(|z| chunk_plan(prog, z));
    let mut report = IndexedRun::default();
    let view = cs.range(0, cs.n_events);
    run_range_inner(prog, &view, hist, true, plan.as_ref(), &mut report)?;
    Ok(report)
}

/// Run a compiled program over an event window of a partition. This is the
/// morsel execution primitive: the view is zero-copy, and for a fixed
/// program the concatenation of adjacent windows produces exactly the fill
/// sequence of one full-partition run.
pub fn run_range(
    prog: &CompiledProgram,
    view: &ColumnRange<'_>,
    hist: &mut H1,
) -> Result<(), String> {
    run_range_inner(prog, view, hist, true, None, &mut IndexedRun::default())
}

/// `run`, but with the chunked kernel disabled — the closure-graph fused
/// loop runs instead. Exists so benches and tests can measure/verify the
/// two lowerings against each other.
pub fn run_scalar(prog: &CompiledProgram, cs: &ColumnSet, hist: &mut H1) -> Result<(), String> {
    let view = cs.range(0, cs.n_events);
    run_range_inner(prog, &view, hist, false, None, &mut IndexedRun::default())
}

fn run_range_inner(
    prog: &CompiledProgram,
    view: &ColumnRange<'_>,
    hist: &mut H1,
    allow_chunked: bool,
    plan: Option<&ChunkPlan>,
    report: &mut IndexedRun,
) -> Result<(), String> {
    let mut ctx = bind(prog, view)?;
    if let Some(f) = &prog.fused {
        let off = ctx.offsets[f.list];
        let k_lo = off[view.ev_lo] as usize;
        let k_hi = off[view.ev_hi] as usize;
        // The chunked kernel indexes content slices directly; confirm they
        // cover the item range first (the scalar path bounds-checks every
        // load and reports OOB through the sticky flag instead).
        let in_bounds = ctx.item_cols.iter().all(|c| c.len() >= k_hi);
        match &f.chunked {
            Some(ck) if allow_chunked && in_bounds => {
                run_chunked(ck, &ctx.item_cols, k_lo, k_hi, hist, plan, report);
            }
            _ => {
                for k in k_lo..k_hi {
                    ctx.slots[f.slot] = k as f64;
                    for s in &f.body {
                        s(&mut ctx, hist);
                    }
                }
            }
        }
    } else {
        for ev in view.ev_lo..view.ev_hi {
            ctx.event = ev;
            for s in &prog.body {
                s(&mut ctx, hist);
            }
        }
    }
    if ctx.oob.get() {
        return Err("compiled query read out of bounds (index past list end?)".to_string());
    }
    Ok(())
}

/// Morsel-driven parallel execution of one partition: split the event range
/// into cache-sized morsels, let a scoped thread pool pull morsel indices
/// from a shared atomic counter (HyPer-style work stealing — fast threads
/// take more morsels, stragglers hurt at most one morsel), and merge the
/// per-morsel histograms **in morsel order** so the result is independent
/// of scheduling. Bin contents and counts match the sequential run exactly;
/// the running `sum`/`sum2` moments may differ in the last ulps because
/// merging reassociates their additions across morsel boundaries.
///
/// Each morsel binds a fresh slot file. A program that reads a variable it
/// has not assigned in the current event would observe stale state in a
/// sequential run and zeros at a morsel (or partition) boundary — the same
/// unspecified edge the distributed partition split already has.
pub fn run_parallel(
    prog: &CompiledProgram,
    cs: &ColumnSet,
    hist: &mut H1,
    cfg: ParallelCfg,
) -> Result<(), String> {
    run_parallel_indexed(prog, cs, None, hist, cfg).map(|_| ())
}

/// `run_parallel` with zone-map chunk skipping: the partition's chunk
/// classification is computed once and every morsel consults it (zone
/// chunks are item-aligned, so a morsel window covering part of a skipped
/// chunk still skips its part). Bins and counts match the unindexed
/// sequential run exactly; the returned report merges all morsels'
/// reports, with every zone chunk counted once (see [`IndexedRun`]).
pub fn run_parallel_indexed(
    prog: &CompiledProgram,
    cs: &ColumnSet,
    zm: Option<&ZoneMap>,
    hist: &mut H1,
    cfg: ParallelCfg,
) -> Result<IndexedRun, String> {
    let plan = zm.and_then(|z| chunk_plan(prog, z));
    let plan = plan.as_ref();
    let morsel = cfg.resolved_morsel_events();
    let n_morsels = cs.n_events.div_ceil(morsel.max(1)).max(1);
    let threads = cfg.resolved_threads().min(n_morsels);
    let mut report = IndexedRun::default();
    if threads <= 1 {
        let view = cs.range(0, cs.n_events);
        run_range_inner(prog, &view, hist, true, plan, &mut report)?;
        return Ok(report);
    }
    let (n_bins, lo, hi) = (hist.n_bins(), hist.lo, hist.hi);
    let next = AtomicUsize::new(0);
    type MorselOut = (Vec<(usize, Result<H1, String>)>, IndexedRun);
    let outs: Vec<MorselOut> = std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(threads);
        for _ in 0..threads {
            handles.push(s.spawn(|| {
                let mut done = Vec::new();
                let mut local = IndexedRun::default();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n_morsels {
                        break;
                    }
                    let ev_lo = i * morsel;
                    let ev_hi = ((i + 1) * morsel).min(cs.n_events);
                    let mut h = H1::new(n_bins, lo, hi);
                    let view = cs.range(ev_lo, ev_hi);
                    let r = run_range_inner(prog, &view, &mut h, true, plan, &mut local);
                    done.push((i, r.map(|_| h)));
                }
                (done, local)
            }));
        }
        handles
            .into_iter()
            .map(|h| h.join().expect("morsel thread panicked"))
            .collect()
    });
    let mut results = Vec::with_capacity(n_morsels);
    for (done, local) in outs {
        results.extend(done);
        report.absorb(&local);
    }
    results.sort_by_key(|(i, _)| *i);
    let mut parts = Vec::with_capacity(results.len());
    for (_, r) in results {
        parts.push(r?);
    }
    hist.merge_many(&parts)?;
    Ok(report)
}

// --------------------------------------------------------- chunked kernel

/// A fused body lowered for batch evaluation: a table of distinct batch
/// expressions (`bufs`) evaluated once per chunk into `CHUNK`-wide `f64`
/// buffers, plus the fill sites that read them. Cut masks, fill values and
/// fill weights all live in the same table, so an expression shared by
/// several sites — the same cut guarding two fills, a common weight, the
/// same value filled under different cuts — is evaluated once per chunk.
struct ChunkedBody {
    bufs: Vec<BExpr>,
    fills: Vec<FillSite>,
    /// Buffers referenced only as cut masks — on a take-all chunk (mask
    /// proven true everywhere by the zone map) their evaluation is skipped
    /// along with the masks themselves.
    mask_only: Vec<bool>,
}

/// One `Fill` of a chunked body, as indices into the shared buffer table.
struct FillSite {
    /// 0/1 cut mask (the conjunction of every enclosing `if`, with `else`
    /// branches negated); `None` means the fill is unconditional.
    mask: Option<usize>,
    /// The fill value.
    expr: usize,
    /// The fill weight; `None` means weight 1.
    weight: Option<usize>,
}

/// Batch expression: the fused loop body re-expressed over the loop index.
/// Every node evaluates a whole chunk into an `&mut [f64]` with simple
/// element-wise loops that LLVM autovectorizes; there is no per-element
/// dispatch left.
enum BExpr {
    Const(f64),
    /// The global item index `k` as f64.
    Idx,
    /// `item_cols[col][k]` — loads are contiguous in a fused loop.
    Load(usize),
    Bin(BinOp, Box<BExpr>, Box<BExpr>),
    Cmp(CmpOp, Box<BExpr>, Box<BExpr>),
    And(Box<BExpr>, Box<BExpr>),
    Or(Box<BExpr>, Box<BExpr>),
    Not(Box<BExpr>),
    Neg(Box<BExpr>),
    Call1(fn(f64) -> f64, Box<BExpr>),
    Call2(fn(f64, f64) -> f64, Box<BExpr>, Box<BExpr>),
}

/// Recognize the shape `try_fuse` emits — exactly one total loop over one
/// list — and decompose it for range-aware execution. Anything else keeps
/// the general per-event body path.
fn compile_fused(block: &[CStmt]) -> Result<Option<FusedLoop>, String> {
    let [CStmt::LoopRange { slot, lo, hi, body }] = block else {
        return Ok(None);
    };
    if !matches!(lo, CExpr::Const(c) if *c == 0.0) {
        return Ok(None);
    }
    let list = match hi {
        CExpr::Call(name, args) if *name == "__list_total" && args.len() == 1 => {
            match &args[0] {
                CExpr::Const(lid) => *lid as usize,
                _ => return Ok(None),
            }
        }
        _ => return Ok(None),
    };
    Ok(Some(FusedLoop {
        list,
        slot: *slot,
        body: compile_block(body)?,
        chunked: compile_chunked(body, *slot),
    }))
}

/// Try to lower a fused loop body to the chunked kernel. The body may be
/// any tree of `if` cuts around `Fill` statements (`try_fuse` admits
/// nothing else): every cut condition becomes a 0/1 mask buffer, nested
/// cuts combine by conjunction (`else` branches by negation), and each
/// fill site records which mask/value/weight buffers it reads. Distinct
/// expressions are interned into one shared buffer table keyed by their
/// folded `CExpr`, so structurally equal subexpressions across fill sites
/// are evaluated once per chunk. `fold` is applied before interning so the
/// scalar and batch lowerings see identical arithmetic.
///
/// Returns `None` — the fused loop then runs the scalar closure body —
/// only when some expression tree exceeds `MAX_BATCH_DEPTH`.
fn compile_chunked(body: &[CStmt], slot: usize) -> Option<ChunkedBody> {
    let mut b = ChunkedBuilder {
        slot,
        keys: Vec::new(),
        bufs: Vec::new(),
        fills: Vec::new(),
    };
    b.block(body, None)?;
    if b.fills.is_empty() {
        return None;
    }
    let mut used_value = vec![false; b.bufs.len()];
    let mut used_mask = vec![false; b.bufs.len()];
    for f in &b.fills {
        used_value[f.expr] = true;
        if let Some(w) = f.weight {
            used_value[w] = true;
        }
        if let Some(m) = f.mask {
            used_mask[m] = true;
        }
    }
    let mask_only = used_mask.iter().zip(&used_value).map(|(m, v)| *m && !*v).collect();
    Some(ChunkedBody {
        bufs: b.bufs,
        fills: b.fills,
        mask_only,
    })
}

/// Interning builder for `ChunkedBody`: batch expressions are keyed by
/// their folded `CExpr` so equal masks, values and weights share a buffer.
struct ChunkedBuilder {
    slot: usize,
    keys: Vec<CExpr>,
    bufs: Vec<BExpr>,
    fills: Vec<FillSite>,
}

impl ChunkedBuilder {
    fn intern(&mut self, e: &CExpr) -> Option<usize> {
        let folded = fold(e);
        if let Some(i) = self.keys.iter().position(|k| *k == folded) {
            return Some(i);
        }
        let batch = batch_compile(&folded, self.slot)?;
        if depth(&batch) > MAX_BATCH_DEPTH {
            return None;
        }
        self.keys.push(folded);
        self.bufs.push(batch);
        Some(self.bufs.len() - 1)
    }

    /// Walk a statement block under the cut mask `mask` (`None` at the top
    /// level), flattening nested `if`s into mask conjunctions.
    fn block(&mut self, stmts: &[CStmt], mask: Option<&CExpr>) -> Option<()> {
        for s in stmts {
            match s {
                CStmt::Fill { expr, weight } => {
                    let expr = self.intern(expr)?;
                    let weight = match weight {
                        Some(w) => Some(self.intern(w)?),
                        None => None,
                    };
                    let mask = match mask {
                        Some(m) => Some(self.intern(m)?),
                        None => None,
                    };
                    self.fills.push(FillSite {
                        mask,
                        expr,
                        weight,
                    });
                }
                CStmt::If { cond, then, els } => {
                    // Truthiness matches the scalar closure: a branch is
                    // taken when `cond != 0.0` — NaN conditions select the
                    // then-branch on both paths, since `NaN != 0.0` holds.
                    self.block(then, Some(&conjoin(mask, cond)))?;
                    if !els.is_empty() {
                        let negated = CExpr::Not(Box::new(cond.clone()));
                        self.block(els, Some(&conjoin(mask, &negated)))?;
                    }
                }
                // `try_fuse` admits only Fill and If inside a fused body;
                // anything else keeps the scalar loop.
                _ => return None,
            }
        }
        Some(())
    }
}

/// The mask of a nested cut: the enclosing mask AND this condition.
fn conjoin(mask: Option<&CExpr>, cond: &CExpr) -> CExpr {
    match mask {
        Some(m) => CExpr::And(Box::new(m.clone()), Box::new(cond.clone())),
        None => cond.clone(),
    }
}

fn batch_compile(e: &CExpr, slot: usize) -> Option<BExpr> {
    Some(match e {
        CExpr::Const(n) => BExpr::Const(*n),
        CExpr::Slot(s) if *s == slot => BExpr::Idx,
        // Any other slot would be per-event state — not fusable anyway.
        CExpr::Slot(_) => return None,
        CExpr::LoadItem { col, idx } => match batch_compile(idx, slot)? {
            // Only direct loads at the loop index are contiguous; computed
            // indices stay on the bounds-checked scalar path.
            BExpr::Idx => BExpr::Load(*col),
            _ => return None,
        },
        CExpr::LoadEvent { .. } | CExpr::ListLen { .. } => return None,
        CExpr::Bin(op, l, r) => BExpr::Bin(
            *op,
            Box::new(batch_compile(l, slot)?),
            Box::new(batch_compile(r, slot)?),
        ),
        CExpr::Cmp(op, l, r) => BExpr::Cmp(
            *op,
            Box::new(batch_compile(l, slot)?),
            Box::new(batch_compile(r, slot)?),
        ),
        CExpr::And(l, r) => BExpr::And(
            Box::new(batch_compile(l, slot)?),
            Box::new(batch_compile(r, slot)?),
        ),
        CExpr::Or(l, r) => BExpr::Or(
            Box::new(batch_compile(l, slot)?),
            Box::new(batch_compile(r, slot)?),
        ),
        CExpr::Not(x) => BExpr::Not(Box::new(batch_compile(x, slot)?)),
        CExpr::Neg(x) => BExpr::Neg(Box::new(batch_compile(x, slot)?)),
        CExpr::Call(name, args) => {
            let one = |f: fn(f64) -> f64, args: &[CExpr]| -> Option<BExpr> {
                Some(BExpr::Call1(f, Box::new(batch_compile(&args[0], slot)?)))
            };
            let two = |f: fn(f64, f64) -> f64, args: &[CExpr]| -> Option<BExpr> {
                Some(BExpr::Call2(
                    f,
                    Box::new(batch_compile(&args[0], slot)?),
                    Box::new(batch_compile(&args[1], slot)?),
                ))
            };
            match (*name, args.len()) {
                ("sqrt", 1) => one(f64::sqrt, args)?,
                ("cosh", 1) => one(f64::cosh, args)?,
                ("cos", 1) => one(f64::cos, args)?,
                ("sinh", 1) => one(f64::sinh, args)?,
                ("sin", 1) => one(f64::sin, args)?,
                ("exp", 1) => one(f64::exp, args)?,
                ("log", 1) => one(f64::ln, args)?,
                ("abs", 1) => one(f64::abs, args)?,
                ("min", 2) => two(f64::min, args)?,
                ("max", 2) => two(f64::max, args)?,
                // __list_base / __list_total and anything unknown.
                _ => return None,
            }
        }
    })
}

fn depth(e: &BExpr) -> usize {
    1 + match e {
        BExpr::Const(_) | BExpr::Idx | BExpr::Load(_) => 0,
        BExpr::Bin(_, l, r)
        | BExpr::Cmp(_, l, r)
        | BExpr::And(l, r)
        | BExpr::Or(l, r)
        | BExpr::Call2(_, l, r) => depth(l).max(depth(r)),
        BExpr::Not(x) | BExpr::Neg(x) | BExpr::Call1(_, x) => depth(x),
    }
}

/// Evaluate a batch expression for items `[base, base + out.len())` into
/// `out`. Each node is one tight element-wise loop; the per-element
/// arithmetic (ops, order, f32→f64 widening, comparison encodings) is
/// bit-identical to the closure graph so the two lowerings agree exactly.
fn beval(e: &BExpr, cols: &[&[f32]], base: usize, out: &mut [f64]) {
    let n = out.len();
    match e {
        BExpr::Const(c) => out.fill(*c),
        BExpr::Idx => {
            for (i, o) in out.iter_mut().enumerate() {
                *o = (base + i) as f64;
            }
        }
        BExpr::Load(col) => {
            let src = &cols[*col][base..base + n];
            for (o, &v) in out.iter_mut().zip(src) {
                *o = v as f64;
            }
        }
        BExpr::Bin(op, l, r) => {
            let mut tb = [0.0f64; CHUNK];
            let t = &mut tb[..n];
            beval(l, cols, base, out);
            beval(r, cols, base, t);
            match op {
                BinOp::Add => {
                    for (o, &v) in out.iter_mut().zip(t.iter()) {
                        *o += v;
                    }
                }
                BinOp::Sub => {
                    for (o, &v) in out.iter_mut().zip(t.iter()) {
                        *o -= v;
                    }
                }
                BinOp::Mul => {
                    for (o, &v) in out.iter_mut().zip(t.iter()) {
                        *o *= v;
                    }
                }
                BinOp::Div => {
                    for (o, &v) in out.iter_mut().zip(t.iter()) {
                        *o /= v;
                    }
                }
            }
        }
        BExpr::Cmp(op, l, r) => {
            let mut tb = [0.0f64; CHUNK];
            let t = &mut tb[..n];
            beval(l, cols, base, out);
            beval(r, cols, base, t);
            match op {
                CmpOp::Lt => {
                    for (o, &v) in out.iter_mut().zip(t.iter()) {
                        *o = (*o < v) as i64 as f64;
                    }
                }
                CmpOp::Le => {
                    for (o, &v) in out.iter_mut().zip(t.iter()) {
                        *o = (*o <= v) as i64 as f64;
                    }
                }
                CmpOp::Gt => {
                    for (o, &v) in out.iter_mut().zip(t.iter()) {
                        *o = (*o > v) as i64 as f64;
                    }
                }
                CmpOp::Ge => {
                    for (o, &v) in out.iter_mut().zip(t.iter()) {
                        *o = (*o >= v) as i64 as f64;
                    }
                }
                CmpOp::Eq => {
                    for (o, &v) in out.iter_mut().zip(t.iter()) {
                        *o = (*o == v) as i64 as f64;
                    }
                }
                CmpOp::Ne => {
                    for (o, &v) in out.iter_mut().zip(t.iter()) {
                        *o = (*o != v) as i64 as f64;
                    }
                }
            }
        }
        // Fused bodies are side-effect-free, so evaluating both operands
        // and combining is value-identical to the short-circuit closures.
        BExpr::And(l, r) => {
            let mut tb = [0.0f64; CHUNK];
            let t = &mut tb[..n];
            beval(l, cols, base, out);
            beval(r, cols, base, t);
            for (o, &v) in out.iter_mut().zip(t.iter()) {
                *o = (*o != 0.0 && v != 0.0) as i64 as f64;
            }
        }
        BExpr::Or(l, r) => {
            let mut tb = [0.0f64; CHUNK];
            let t = &mut tb[..n];
            beval(l, cols, base, out);
            beval(r, cols, base, t);
            for (o, &v) in out.iter_mut().zip(t.iter()) {
                *o = (*o != 0.0 || v != 0.0) as i64 as f64;
            }
        }
        BExpr::Not(x) => {
            beval(x, cols, base, out);
            for o in out.iter_mut() {
                *o = (*o == 0.0) as i64 as f64;
            }
        }
        BExpr::Neg(x) => {
            beval(x, cols, base, out);
            for o in out.iter_mut() {
                *o = -*o;
            }
        }
        BExpr::Call1(f, x) => {
            beval(x, cols, base, out);
            for o in out.iter_mut() {
                *o = f(*o);
            }
        }
        BExpr::Call2(f, l, r) => {
            let mut tb = [0.0f64; CHUNK];
            let t = &mut tb[..n];
            beval(l, cols, base, out);
            beval(r, cols, base, t);
            for (o, &v) in out.iter_mut().zip(t.iter()) {
                *o = f(*o, v);
            }
        }
    }
}

/// Run the chunked kernel for items `[k_lo, k_hi)`: evaluate every buffer
/// of the shared expression table one chunk at a time, then accumulate all
/// fill sites with a branch-free select chain into a scratch histogram
/// (`n_bins` bins + an underflow and an overflow slot).
///
/// Chunks align to absolute `CHUNK` boundaries (the first batch may be
/// short), so each batch maps to exactly one zone-map chunk and `plan` can
/// decide it: `Skip` does nothing, `TakeAll` drops the masks (and skips
/// evaluating mask-only buffers), `Scan` is the normal masked pass.
/// Boundary placement cannot change the result — accumulation is
/// sequential and item-major across batches.
///
/// Bit-identity with the scalar fused loop holds by construction:
///   * accumulation is item-major, fill-site-minor — exactly the statement
///     order of the scalar loop — and the running moments use one
///     sequential accumulator across the whole range;
///   * a masked-out (or NaN, matching `H1::fill_w`) fill contributes
///     `+0.0` with its value selected to `0.0`, a bit-exact no-op on every
///     accumulator this kernel can produce: accumulators start at `+0.0`
///     and can never reach `-0.0` (the only value `+0.0` would perturb),
///     so the mask replaces the scalar loop's branch without changing a
///     single bit. A `Skip` chunk removes only such no-op contributions; a
///     `TakeAll` chunk's masks would have been 1 at every item.
fn run_chunked(
    ck: &ChunkedBody,
    cols: &[&[f32]],
    k_lo: usize,
    k_hi: usize,
    hist: &mut H1,
    plan: Option<&ChunkPlan>,
    report: &mut IndexedRun,
) {
    let n_bins = hist.n_bins();
    let lo = hist.lo;
    let width = hist.hi - hist.lo;
    let mut scratch = vec![0.0f64; n_bins + 2];
    let (mut count, mut sum, mut sum2) = (0.0f64, 0.0f64, 0.0f64);
    // One chunk-wide buffer per distinct batch expression; allocated once
    // per kernel run (= once per morsel), reused across chunks.
    let mut bufs: Vec<Vec<f64>> = ck.bufs.iter().map(|_| vec![0.0f64; CHUNK]).collect();
    let mut base = k_lo;
    while base < k_hi {
        let n = (CHUNK - base % CHUNK).min(k_hi - base);
        let decision = match plan {
            Some(p) => match p.decisions.get(base / CHUNK) {
                Some(d) => *d,
                None => ZoneDecision::Scan,
            },
            None => ZoneDecision::Scan,
        };
        // Count each zone chunk once even when morsel windows split it:
        // only the batch that starts at the chunk boundary reports it
        // (the union of morsel windows covers every boundary exactly
        // once, so the per-run totals stay honest chunk counts).
        let counted = plan.is_some() && base % CHUNK == 0;
        if decision == ZoneDecision::Skip {
            if counted {
                report.chunks_skipped += 1;
            }
            base += n;
            continue;
        }
        let take_all = decision == ZoneDecision::TakeAll;
        if counted {
            if take_all {
                report.chunks_take_all += 1;
            } else {
                report.chunks_scanned += 1;
            }
        }
        for (bi, (e, buf)) in ck.bufs.iter().zip(bufs.iter_mut()).enumerate() {
            if take_all && ck.mask_only[bi] {
                continue;
            }
            beval(e, cols, base, &mut buf[..n]);
        }
        // Resolve each fill site's buffers once per chunk; the item-major
        // loop below then replays the scalar loop's operation sequence.
        let views: Vec<(Option<&[f64]>, &[f64], Option<&[f64]>)> = ck
            .fills
            .iter()
            .map(|f| {
                let mask = if take_all { None } else { f.mask };
                (
                    mask.map(|m| &bufs[m][..n]),
                    &bufs[f.expr][..n],
                    f.weight.map(|w| &bufs[w][..n]),
                )
            })
            .collect();
        for i in 0..n {
            for &(mask, xs, ws) in &views {
                let live = match mask {
                    Some(m) => m[i] != 0.0,
                    None => true,
                };
                let x = xs[i];
                // Cut mask and NaN-skip as data flow, not branches.
                let ok = live && !x.is_nan();
                let xv = if ok { x } else { 0.0 };
                let w = match ws {
                    Some(wb) => wb[i],
                    None => 1.0,
                };
                let wv = if ok { w } else { 0.0 };
                // Same index arithmetic as H1::bin_index; the selects
                // compile to cmovs, not branches.
                let t = (xv - lo) / width * n_bins as f64;
                let bi = t as usize; // saturating: t >= 0 here when xv >= lo
                let idx = if xv < lo {
                    n_bins
                } else if bi < n_bins {
                    bi
                } else {
                    n_bins + 1
                };
                scratch[idx] += wv;
                count += wv;
                sum += wv * xv;
                sum2 += wv * xv * xv;
            }
        }
        base += n;
    }
    for (b, s) in hist.bins.iter_mut().zip(&scratch) {
        *b += s;
    }
    hist.underflow += scratch[n_bins];
    hist.overflow += scratch[n_bins + 1];
    hist.count += count;
    hist.sum += sum;
    hist.sum2 += sum2;
}

// ------------------------------------------------------- closure lowering

fn compile_block(stmts: &[CStmt]) -> Result<Vec<StmtFn>, String> {
    stmts.iter().map(compile_stmt).collect()
}

fn compile_stmt(s: &CStmt) -> Result<StmtFn, String> {
    Ok(match s {
        CStmt::Assign { slot, expr } => {
            let slot = *slot;
            let e = compile_expr(&fold(expr))?;
            Box::new(move |c: &mut Ctx, _h: &mut H1| {
                let v = e(c);
                c.slots[slot] = v;
            })
        }
        CStmt::LoopRange { slot, lo, hi, body } => {
            let slot = *slot;
            let lo = compile_expr(&fold(lo))?;
            let hi = compile_expr(&fold(hi))?;
            let body = compile_block(body)?;
            Box::new(move |c: &mut Ctx, h: &mut H1| {
                let l = lo(c) as i64;
                let u = hi(c) as i64;
                for k in l..u {
                    c.slots[slot] = k as f64;
                    for s in &body {
                        s(c, h);
                    }
                }
            })
        }
        CStmt::LoopList { list, slot, body } => {
            let list = *list;
            let slot = *slot;
            let body = compile_block(body)?;
            Box::new(move |c: &mut Ctx, h: &mut H1| {
                let off = c.offsets[list];
                let (l, u) = (off[c.event], off[c.event + 1]);
                for k in l..u {
                    c.slots[slot] = k as f64;
                    for s in &body {
                        s(c, h);
                    }
                }
            })
        }
        CStmt::If { cond, then, els } => {
            let cond = compile_expr(&fold(cond))?;
            let then = compile_block(then)?;
            let els = compile_block(els)?;
            Box::new(move |c: &mut Ctx, h: &mut H1| {
                let branch = if cond(c) != 0.0 { &then } else { &els };
                for s in branch {
                    s(c, h);
                }
            })
        }
        CStmt::Fill { expr, weight } => {
            let e = compile_expr(&fold(expr))?;
            match weight {
                None => Box::new(move |c: &mut Ctx, h: &mut H1| {
                    let x = e(c);
                    h.fill(x);
                }),
                Some(w) => {
                    let w = compile_expr(&fold(w))?;
                    Box::new(move |c: &mut Ctx, h: &mut H1| {
                        let x = e(c);
                        let wt = w(c);
                        h.fill_w(x, wt);
                    })
                }
            }
        }
    })
}

/// Constant folding over a compiled expression tree. Pure arithmetic on
/// constants is evaluated at lower time; everything else is rebuilt with
/// folded children. Comparisons, booleans and builtins are deliberately not
/// folded so runtime semantics (short-circuit order, NaN behaviour) stay
/// byte-identical with the interpreters.
fn fold(e: &CExpr) -> CExpr {
    match e {
        CExpr::Bin(op, l, r) => {
            let (l, r) = (fold(l), fold(r));
            if let (CExpr::Const(a), CExpr::Const(b)) = (&l, &r) {
                return CExpr::Const(match op {
                    BinOp::Add => a + b,
                    BinOp::Sub => a - b,
                    BinOp::Mul => a * b,
                    BinOp::Div => a / b,
                });
            }
            CExpr::Bin(*op, Box::new(l), Box::new(r))
        }
        CExpr::Neg(x) => {
            let x = fold(x);
            if let CExpr::Const(a) = &x {
                return CExpr::Const(-a);
            }
            CExpr::Neg(Box::new(x))
        }
        CExpr::Cmp(op, l, r) => CExpr::Cmp(*op, Box::new(fold(l)), Box::new(fold(r))),
        CExpr::And(l, r) => CExpr::And(Box::new(fold(l)), Box::new(fold(r))),
        CExpr::Or(l, r) => CExpr::Or(Box::new(fold(l)), Box::new(fold(r))),
        CExpr::Not(x) => CExpr::Not(Box::new(fold(x))),
        CExpr::LoadItem { col, idx } => CExpr::LoadItem {
            col: *col,
            idx: Box::new(fold(idx)),
        },
        CExpr::Call(name, args) => CExpr::Call(*name, args.iter().map(fold).collect()),
        other => other.clone(),
    }
}

fn unary(mut args: Vec<ExprFn>, f: fn(f64) -> f64) -> ExprFn {
    let a = args.pop().unwrap();
    Box::new(move |c: &Ctx| f(a(c)))
}

fn binary(mut args: Vec<ExprFn>, f: fn(f64, f64) -> f64) -> ExprFn {
    let b = args.pop().unwrap();
    let a = args.pop().unwrap();
    Box::new(move |c: &Ctx| f(a(c), b(c)))
}

fn compile_expr(e: &CExpr) -> Result<ExprFn, String> {
    Ok(match e {
        CExpr::Const(n) => {
            let n = *n;
            Box::new(move |_c: &Ctx| n)
        }
        CExpr::Slot(s) => {
            let s = *s;
            Box::new(move |c: &Ctx| c.slots[s])
        }
        CExpr::LoadItem { col, idx } => {
            let col = *col;
            let idx = compile_expr(idx)?;
            Box::new(move |c: &Ctx| {
                let k = idx(c) as usize;
                match c.item_cols[col].get(k) {
                    Some(&v) => v as f64,
                    None => {
                        c.oob.set(true);
                        0.0
                    }
                }
            })
        }
        CExpr::LoadEvent { col } => {
            let col = *col;
            Box::new(move |c: &Ctx| {
                match c.event_cols[col].get(c.event) {
                    Some(&v) => v as f64,
                    None => {
                        c.oob.set(true);
                        0.0
                    }
                }
            })
        }
        CExpr::ListLen { list } => {
            let list = *list;
            Box::new(move |c: &Ctx| {
                let off = c.offsets[list];
                (off[c.event + 1] - off[c.event]) as f64
            })
        }
        CExpr::Bin(op, l, r) => {
            let l = compile_expr(l)?;
            let r = compile_expr(r)?;
            match op {
                BinOp::Add => Box::new(move |c: &Ctx| l(c) + r(c)),
                BinOp::Sub => Box::new(move |c: &Ctx| l(c) - r(c)),
                BinOp::Mul => Box::new(move |c: &Ctx| l(c) * r(c)),
                BinOp::Div => Box::new(move |c: &Ctx| l(c) / r(c)),
            }
        }
        CExpr::Cmp(op, l, r) => {
            let l = compile_expr(l)?;
            let r = compile_expr(r)?;
            match op {
                CmpOp::Lt => Box::new(move |c: &Ctx| (l(c) < r(c)) as i64 as f64),
                CmpOp::Le => Box::new(move |c: &Ctx| (l(c) <= r(c)) as i64 as f64),
                CmpOp::Gt => Box::new(move |c: &Ctx| (l(c) > r(c)) as i64 as f64),
                CmpOp::Ge => Box::new(move |c: &Ctx| (l(c) >= r(c)) as i64 as f64),
                CmpOp::Eq => Box::new(move |c: &Ctx| (l(c) == r(c)) as i64 as f64),
                CmpOp::Ne => Box::new(move |c: &Ctx| (l(c) != r(c)) as i64 as f64),
            }
        }
        CExpr::And(l, r) => {
            let l = compile_expr(l)?;
            let r = compile_expr(r)?;
            Box::new(move |c: &Ctx| {
                if l(c) != 0.0 {
                    (r(c) != 0.0) as i64 as f64
                } else {
                    0.0
                }
            })
        }
        CExpr::Or(l, r) => {
            let l = compile_expr(l)?;
            let r = compile_expr(r)?;
            Box::new(move |c: &Ctx| {
                if l(c) != 0.0 {
                    1.0
                } else {
                    (r(c) != 0.0) as i64 as f64
                }
            })
        }
        CExpr::Not(x) => {
            let x = compile_expr(x)?;
            Box::new(move |c: &Ctx| (x(c) == 0.0) as i64 as f64)
        }
        CExpr::Neg(x) => {
            let x = compile_expr(x)?;
            Box::new(move |c: &Ctx| -x(c))
        }
        CExpr::Call(name, args) => match *name {
            "__list_base" => {
                let CExpr::Const(lid) = &args[0] else {
                    return Err("__list_base: non-constant list id".to_string());
                };
                let lid = *lid as usize;
                let j = compile_expr(&args[1])?;
                Box::new(move |c: &Ctx| c.offsets[lid][c.event] as f64 + j(c))
            }
            "__list_total" => {
                let CExpr::Const(lid) = &args[0] else {
                    return Err("__list_total: non-constant list id".to_string());
                };
                let lid = *lid as usize;
                // Total items of the context's event *window*, so fused
                // loops compiled through the generic path stay range-safe.
                Box::new(move |c: &Ctx| c.offsets[lid][c.ev_hi] as f64)
            }
            _ => {
                let mut cargs = Vec::with_capacity(args.len());
                for a in args {
                    cargs.push(compile_expr(a)?);
                }
                match (*name, cargs.len()) {
                    ("sqrt", 1) => unary(cargs, f64::sqrt),
                    ("cosh", 1) => unary(cargs, f64::cosh),
                    ("cos", 1) => unary(cargs, f64::cos),
                    ("sinh", 1) => unary(cargs, f64::sinh),
                    ("sin", 1) => unary(cargs, f64::sin),
                    ("exp", 1) => unary(cargs, f64::exp),
                    ("log", 1) => unary(cargs, f64::ln),
                    ("abs", 1) => unary(cargs, f64::abs),
                    ("min", 2) => binary(cargs, f64::min),
                    ("max", 2) => binary(cargs, f64::max),
                    (n, k) => {
                        return Err(format!("cannot lower builtin '{n}' with {k} args"))
                    }
                }
            }
        },
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::{generate_drellyan, generate_ttbar};
    use crate::queryir::{self, flat, table3};

    /// The compiled closure graph must agree bin-exactly with the flat
    /// evaluator (and transitively the tape VM and object interpreter) on
    /// every Table-3 program.
    #[test]
    fn compiled_equals_flat_on_table3() {
        let cs = generate_drellyan(3000, 91);
        for src in [
            table3::MAX_PT,
            table3::ETA_BEST,
            table3::PTSUM_PAIRS,
            table3::MASS_PAIRS,
            table3::MUON_PT,
        ] {
            let prog = queryir::compile(src, &cs.schema).unwrap();
            let cp = lower(&prog).unwrap();
            let mut h_flat = H1::new(64, -10.0, 250.0);
            flat::run(&prog, &cs, &mut h_flat).unwrap();
            let mut h_comp = H1::new(64, -10.0, 250.0);
            run(&cp, &cs, &mut h_comp).unwrap();
            assert_eq!(h_comp.bins, h_flat.bins);
            assert_eq!(h_comp.total(), h_flat.total());
        }
    }

    #[test]
    fn short_circuit_semantics() {
        let cs = generate_drellyan(500, 92);
        let src = "\
for event in dataset:
    n = len(event.muons)
    for muon in event.muons:
        if n > 0 and muon.pt / n > 1:
            if muon.eta < 0 or muon.pt > 20:
                fill(muon.pt)
";
        let prog = queryir::compile(src, &cs.schema).unwrap();
        let cp = lower(&prog).unwrap();
        let mut h_flat = H1::new(32, 0.0, 128.0);
        flat::run(&prog, &cs, &mut h_flat).unwrap();
        let mut h_comp = H1::new(32, 0.0, 128.0);
        run(&cp, &cs, &mut h_comp).unwrap();
        assert_eq!(h_comp.bins, h_flat.bins);
        assert!(h_comp.total() > 0.0);
    }

    #[test]
    fn weights_and_event_leaves() {
        let cs = generate_drellyan(400, 93);
        let src = "for event in dataset:\n    fill(event.met, 0.5)\n";
        let prog = queryir::compile(src, &cs.schema).unwrap();
        let cp = lower(&prog).unwrap();
        let mut h = H1::new(16, 0.0, 100.0);
        run(&cp, &cs, &mut h).unwrap();
        assert_eq!(h.total(), 200.0);
    }

    #[test]
    fn fused_path_used_and_correct() {
        let cs = generate_drellyan(1000, 94);
        let prog = queryir::compile(table3::MUON_PT, &cs.schema).unwrap();
        assert!(prog.fused.is_some());
        let cp = lower(&prog).unwrap();
        assert!(cp.is_fused());
        let mut h_fused = H1::new(64, 0.0, 128.0);
        run(&cp, &cs, &mut h_fused).unwrap();
        let mut h_flat = H1::new(64, 0.0, 128.0);
        flat::run_unfused(&prog, &cs, &mut h_flat).unwrap();
        assert_eq!(h_fused.bins, h_flat.bins);
    }

    /// The chunked kernel must agree with the closure-graph fused loop to
    /// the last bit — bins, under/overflow and moments — because the
    /// element order and per-element arithmetic are identical.
    #[test]
    fn chunked_kernel_bit_identical_to_scalar() {
        let cs = generate_ttbar(3000, 8, 96);
        let prog = queryir::compile(table3::JET_PT, &cs.schema).unwrap();
        let cp = lower(&prog).unwrap();
        assert!(cp.has_chunked_kernel());
        let mut h_chunk = H1::new(64, 10.0, 200.0); // nonzero lo exercises underflow
        run(&cp, &cs, &mut h_chunk).unwrap();
        let mut h_scalar = H1::new(64, 10.0, 200.0);
        run_scalar(&cp, &cs, &mut h_scalar).unwrap();
        assert_eq!(h_chunk, h_scalar);
        assert!(h_chunk.underflow > 0.0 || h_chunk.overflow > 0.0);
    }

    /// Weighted and compound fill expressions also take the chunked path.
    #[test]
    fn chunked_kernel_weighted_and_compound() {
        let cs = generate_drellyan(2500, 97);
        let src = "\
for event in dataset:
    for muon in event.muons:
        fill(sqrt(muon.pt * muon.pt + muon.eta), 0.25)
";
        let prog = queryir::compile(src, &cs.schema).unwrap();
        let cp = lower(&prog).unwrap();
        assert!(cp.has_chunked_kernel());
        let mut a = H1::new(48, 0.0, 160.0);
        run(&cp, &cs, &mut a).unwrap();
        let mut b = H1::new(48, 0.0, 160.0);
        run_scalar(&cp, &cs, &mut b).unwrap();
        assert_eq!(a, b);
        assert!(a.total() > 0.0);
    }

    /// A fused body with an `if` cut lowers to the masked chunked kernel,
    /// is bit-identical to the scalar closure loop, and stays range-safe
    /// under morsel windows.
    #[test]
    fn fused_with_condition_lowers_to_masked_chunked_kernel() {
        let cs = generate_drellyan(1200, 98);
        let src = "\
for event in dataset:
    for muon in event.muons:
        if muon.pt > 20:
            fill(muon.pt)
";
        let prog = queryir::compile(src, &cs.schema).unwrap();
        assert!(prog.fused.is_some());
        let cp = lower(&prog).unwrap();
        assert!(cp.is_fused());
        assert!(cp.has_chunked_kernel());
        assert_eq!(
            cp.chunked_info(),
            Some(ChunkedInfo {
                fills: 1,
                masked_fills: 1,
                buffers: 2, // the mask and the fill value
            })
        );
        let mut whole = H1::new(64, 0.0, 128.0);
        run(&cp, &cs, &mut whole).unwrap();
        let mut scalar = H1::new(64, 0.0, 128.0);
        run_scalar(&cp, &cs, &mut scalar).unwrap();
        assert_eq!(whole, scalar);
        assert!(whole.total() > 0.0);
        // Adjacent windows tile exactly for bins/count (weight-1 fills);
        // the per-window moment accumulators reassociate sum/sum2.
        let mut halves = H1::new(64, 0.0, 128.0);
        run_range(&cp, &cs.range(0, 600), &mut halves).unwrap();
        run_range(&cp, &cs.range(600, 1200), &mut halves).unwrap();
        assert_eq!(whole.bins, halves.bins);
        assert_eq!(whole.count, halves.count);
    }

    /// Nested cuts (mask conjunction), `else` branches (mask negation) and
    /// NaN-producing fill values all agree with the scalar loop to the bit.
    #[test]
    fn nested_and_else_cuts_bit_identical() {
        let cs = generate_drellyan(2500, 102);
        let src = "\
for event in dataset:
    for muon in event.muons:
        if muon.pt > 10:
            if muon.eta > 0:
                fill(muon.pt, 0.5)
            else:
                fill(sqrt(muon.eta))
        else:
            fill(muon.phi, muon.pt * 0.25)
";
        let prog = queryir::compile(src, &cs.schema).unwrap();
        let cp = lower(&prog).unwrap();
        assert!(cp.has_chunked_kernel());
        let info = cp.chunked_info().unwrap();
        assert_eq!(info.fills, 3);
        assert_eq!(info.masked_fills, 3);
        let mut a = H1::new(48, -3.0, 96.0);
        run(&cp, &cs, &mut a).unwrap();
        let mut b = H1::new(48, -3.0, 96.0);
        run_scalar(&cp, &cs, &mut b).unwrap();
        assert_eq!(a, b);
        // sqrt(eta) is NaN for half the muons; those fills are skipped on
        // both paths, so the total is well below one entry per muon.
        assert!(a.total() > 0.0);
    }

    /// Several `Fill`s run as one shared batch pass: a cut and a weight
    /// common to two fills are interned once in the buffer table.
    #[test]
    fn multi_fill_body_shares_buffers() {
        let cs = generate_drellyan(1500, 103);
        let src = "\
for event in dataset:
    for muon in event.muons:
        if muon.pt > 10:
            fill(muon.pt, 0.5)
            fill(muon.eta, 0.5)
        fill(muon.phi)
";
        let prog = queryir::compile(src, &cs.schema).unwrap();
        let cp = lower(&prog).unwrap();
        assert_eq!(
            cp.chunked_info(),
            Some(ChunkedInfo {
                fills: 3,
                masked_fills: 2,
                // mask, muon.pt, 0.5, muon.eta, muon.phi — the shared cut
                // and the shared weight count once each.
                buffers: 5,
            })
        );
        let mut a = H1::new(64, -4.0, 128.0);
        run(&cp, &cs, &mut a).unwrap();
        let mut b = H1::new(64, -4.0, 128.0);
        run_scalar(&cp, &cs, &mut b).unwrap();
        assert_eq!(a, b);
        assert!(a.total() > 0.0);
    }

    /// The one remaining fused fallback: an expression tree deeper than
    /// `MAX_BATCH_DEPTH` keeps the scalar closure loop (bounded kernel
    /// stack) and still runs correctly.
    #[test]
    fn pathologically_deep_expression_falls_back_to_scalar_loop() {
        let cs = generate_drellyan(300, 104);
        let deep = format!(
            "{}muon.pt{}",
            "sqrt(".repeat(MAX_BATCH_DEPTH + 4),
            ")".repeat(MAX_BATCH_DEPTH + 4)
        );
        let src =
            format!("for event in dataset:\n    for muon in event.muons:\n        fill({deep})\n");
        let prog = queryir::compile(&src, &cs.schema).unwrap();
        let cp = lower(&prog).unwrap();
        assert!(cp.is_fused());
        assert!(!cp.has_chunked_kernel());
        let mut h = H1::new(16, 0.0, 4.0);
        run(&cp, &cs, &mut h).unwrap();
        assert!(h.total() > 0.0);
    }

    /// Adjacent event windows tile a partition exactly: concatenating
    /// `run_range` calls reproduces the full-partition fill sequence.
    #[test]
    fn run_range_windows_tile_the_partition() {
        let cs = generate_drellyan(999, 99);
        for src in [table3::MAX_PT, table3::MASS_PAIRS, table3::MUON_PT] {
            let prog = queryir::compile(src, &cs.schema).unwrap();
            let cp = lower(&prog).unwrap();
            let mut whole = H1::new(64, 0.0, 128.0);
            run(&cp, &cs, &mut whole).unwrap();
            let mut tiled = H1::new(64, 0.0, 128.0);
            let mut ev = 0;
            while ev < cs.n_events {
                let hi = (ev + 130).min(cs.n_events);
                run_range(&cp, &cs.range(ev, hi), &mut tiled).unwrap();
                ev = hi;
            }
            assert_eq!(whole.bins, tiled.bins);
            assert_eq!(whole.total(), tiled.total());
        }
    }

    /// Zone-map chunk skipping: on pt-sorted data a tight cut skips most
    /// chunks, an always-true cut take-alls them, and both stay
    /// bit-identical to the unindexed run.
    #[test]
    fn run_indexed_skips_chunks_bit_identically() {
        let mut cs = generate_drellyan(6_000, 105);
        let mut pts = cs.leaf("muons.pt").unwrap().as_f32().unwrap().to_vec();
        pts.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let thr = pts[pts.len() - 1 - pts.len() / 100] as f64; // ~99th pctile
        let n_items = pts.len();
        cs.leaves
            .insert("muons.pt".into(), crate::columnar::arrays::Array::F32(pts));
        let zm = crate::index::ZoneMap::build(&cs);
        let src = format!(
            "for event in dataset:\n    for muon in event.muons:\n        \
             if muon.pt > {thr}:\n            fill(muon.pt)\n"
        );
        let prog = queryir::compile(&src, &cs.schema).unwrap();
        let cp = lower(&prog).unwrap();
        assert!(cp.is_prunable());
        let mut full = H1::new(64, 0.0, 128.0);
        run(&cp, &cs, &mut full).unwrap();
        let mut indexed = H1::new(64, 0.0, 128.0);
        let rep = run_indexed(&cp, &cs, Some(&zm), &mut indexed).unwrap();
        assert_eq!(indexed, full);
        let n_chunks = n_items.div_ceil(CHUNK) as u64;
        assert_eq!(rep.chunks_skipped + rep.chunks_take_all + rep.chunks_scanned, n_chunks);
        assert!(rep.chunks_skipped >= n_chunks - 2, "{rep:?}");

        // An always-true cut: every chunk runs unmasked.
        let src = "\
for event in dataset:
    for muon in event.muons:
        if muon.pt > -1:
            fill(muon.pt)
";
        let prog = queryir::compile(src, &cs.schema).unwrap();
        let cp = lower(&prog).unwrap();
        let mut full = H1::new(64, 0.0, 128.0);
        run(&cp, &cs, &mut full).unwrap();
        let mut indexed = H1::new(64, 0.0, 128.0);
        let rep = run_indexed(&cp, &cs, Some(&zm), &mut indexed).unwrap();
        assert_eq!(indexed, full);
        assert_eq!(rep.chunks_take_all, n_chunks, "{rep:?}");

        // No zone map → no engagement, same histogram.
        let mut plain = H1::new(64, 0.0, 128.0);
        let rep = run_indexed(&cp, &cs, None, &mut plain).unwrap();
        assert_eq!(plain, full);
        assert_eq!(rep, IndexedRun::default());
    }

    /// Morsel windows that split zone chunks still skip their parts and
    /// agree with the sequential run on bins and count.
    #[test]
    fn run_parallel_indexed_composes_with_morsels() {
        let mut cs = generate_drellyan(4_000, 106);
        let mut pts = cs.leaf("muons.pt").unwrap().as_f32().unwrap().to_vec();
        pts.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let thr = pts[pts.len() / 2] as f64; // interior: all 3 verdicts occur
        cs.leaves
            .insert("muons.pt".into(), crate::columnar::arrays::Array::F32(pts));
        let zm = crate::index::ZoneMap::build(&cs);
        let src = format!(
            "for event in dataset:\n    for muon in event.muons:\n        \
             if muon.pt > {thr}:\n            fill(muon.pt)\n"
        );
        let prog = queryir::compile(&src, &cs.schema).unwrap();
        let cp = lower(&prog).unwrap();
        let mut seq = H1::new(64, 0.0, 128.0);
        run(&cp, &cs, &mut seq).unwrap();
        let cfg = ParallelCfg {
            threads: 4,
            morsel_events: 333,
        };
        let mut par = H1::new(64, 0.0, 128.0);
        let rep = run_parallel_indexed(&cp, &cs, Some(&zm), &mut par, cfg).unwrap();
        assert_eq!(seq.bins, par.bins);
        assert_eq!(seq.count, par.count);
        assert!(rep.chunks_skipped > 0 || rep.chunks_take_all > 0, "{rep:?}");
    }

    #[test]
    fn parallel_matches_sequential_on_pairs() {
        let cs = generate_drellyan(4000, 100);
        let prog = queryir::compile(table3::MASS_PAIRS, &cs.schema).unwrap();
        let cp = lower(&prog).unwrap();
        let mut seq = H1::new(64, 0.0, 128.0);
        run(&cp, &cs, &mut seq).unwrap();
        let mut par = H1::new(64, 0.0, 128.0);
        let cfg = ParallelCfg {
            threads: 4,
            morsel_events: 256,
        };
        run_parallel(&cp, &cs, &mut par, cfg).unwrap();
        assert_eq!(seq.bins, par.bins);
        assert_eq!(seq.count, par.count);
    }

    #[test]
    fn parallel_propagates_errors() {
        let cs = generate_drellyan(300, 101);
        let src = "\
for event in dataset:
    m = event.muons[999]
    fill(m.pt)
";
        let prog = queryir::compile(src, &cs.schema).unwrap();
        let cp = lower(&prog).unwrap();
        let mut h = H1::new(8, 0.0, 128.0);
        let cfg = ParallelCfg {
            threads: 3,
            morsel_events: 64,
        };
        assert!(run_parallel(&cp, &cs, &mut h, cfg).is_err());
    }

    #[test]
    fn constant_folding_folds_arithmetic() {
        let e = CExpr::Bin(
            BinOp::Mul,
            Box::new(CExpr::Const(2.0)),
            Box::new(CExpr::Bin(
                BinOp::Add,
                Box::new(CExpr::Const(3.0)),
                Box::new(CExpr::Const(4.0)),
            )),
        );
        assert_eq!(fold(&e), CExpr::Const(14.0));
        // Non-const subtrees survive.
        let partial = CExpr::Bin(
            BinOp::Add,
            Box::new(CExpr::Slot(0)),
            Box::new(CExpr::Const(1.0)),
        );
        assert_eq!(fold(&partial), partial);
    }

    #[test]
    fn out_of_bounds_index_is_an_error_not_a_panic() {
        let cs = generate_drellyan(50, 95);
        // muons[999] is past the end of the whole content array for every
        // event of a 50-event sample.
        let src = "\
for event in dataset:
    m = event.muons[999]
    fill(m.pt)
";
        let prog = queryir::compile(src, &cs.schema).unwrap();
        let cp = lower(&prog).unwrap();
        let mut h = H1::new(8, 0.0, 128.0);
        assert!(run(&cp, &cs, &mut h).is_err());
    }

    #[test]
    fn fingerprint_is_name_and_whitespace_invariant() {
        let cs = generate_drellyan(1, 96);
        let a = "\
for event in dataset:
    for muon in event.muons:
        fill(muon.pt + 1)
";
        let b = "\
for ev in dataset:
    for m in ev.muons:
        fill(m.pt  +  1)
";
        let c = "\
for ev in dataset:
    for m in ev.muons:
        fill(m.pt + 2)
";
        let fa = fingerprint(&queryir::compile(a, &cs.schema).unwrap());
        let fb = fingerprint(&queryir::compile(b, &cs.schema).unwrap());
        let fc = fingerprint(&queryir::compile(c, &cs.schema).unwrap());
        assert_eq!(fa, fb, "renaming/whitespace must not change the tape hash");
        assert_ne!(fa, fc, "different programs must hash differently");
    }
}
