//! The query language and its code transformation (paper §3).
//!
//! Physicists write object-style loops over events; `transform` rewrites
//! them algorithmically into flat loops over offsets/content arrays;
//! `flat` executes the transformed program with zero materialization, and
//! `interp` executes the *original* program over materialized objects (the
//! baseline the transformation is measured against in Figure 1). `lower`
//! compiles the transformed program to native closures and — for fused
//! shapes, cuts and multi-`fill` bodies included — chunked batch kernels.
//! `predicate` extracts interval constraints from a tape's `if` cuts and
//! evaluates them against zone maps (`crate::index`) so execution can skip
//! partitions and chunks a cut can never select.
//!
//! The language reference (grammar, builtins, cut/fill semantics) lives in
//! `docs/QUERY_LANGUAGE.md`; the stage-by-stage pipeline with its defining
//! files in `docs/ARCHITECTURE.md`.

pub mod ast;
pub mod flat;
pub mod interp;
pub mod lexer;
pub mod lower;
pub mod parser;
pub mod predicate;
pub mod tape;
pub mod transform;

pub use ast::Program;
pub use lower::{
    lower_with_notes, run_fused_group_indexed, run_fused_indexed, ChunkedInfo, CompiledProgram,
    IndexedRun, KernelScratch, KernelShape, ParallelCfg,
};
pub use parser::parse;
pub use predicate::{CutPredicate, ZoneDecision};
pub use transform::{FlatProgram, Transformer};

use crate::columnar::arrays::ColumnSet;
use crate::columnar::schema::Ty;
use crate::hist::H1;

/// One-call compile: source text → transformed flat program.
pub fn compile(src: &str, schema: &Ty) -> Result<FlatProgram, String> {
    let prog = parse(src).map_err(|e| e.to_string())?;
    Transformer::compile(&prog, schema).map_err(|e| e.to_string())
}

/// Parse + transform + run over a partition (the convenient API).
///
/// Uses the AST-walking `flat` evaluator: a postfix-tape VM was built and
/// benchmarked (`queryir::tape`, bench_figure1's "tape VM" series) but
/// measured *slower* on 3 of 4 Table-3 queries — rustc register-allocates
/// the recursive evaluator better than a Vec-backed operand stack — so the
/// tree walker stays the default (EXPERIMENTS.md §Perf).
pub fn run_transformed(src: &str, cs: &ColumnSet, hist: &mut H1) -> Result<(), String> {
    let prog = compile(src, &cs.schema)?;
    flat::run(&prog, cs, hist)
}

/// Parse + run the object interpreter (the baseline API).
pub fn run_object_view(src: &str, cs: &ColumnSet, hist: &mut H1) -> Result<(), String> {
    let prog = parse(src).map_err(|e| e.to_string())?;
    interp::run(&prog, cs, hist)
}

/// The paper's Table-3 analysis functions as query-language source.
pub mod table3 {
    pub const MAX_PT: &str = "\
for event in dataset:
    maximum = 0.0
    n = len(event.muons)
    for muon in event.muons:
        if muon.pt > maximum:
            maximum = muon.pt
    if n > 0:
        fill(maximum)
";

    pub const ETA_BEST: &str = "\
for event in dataset:
    maximum = 0.0
    found = 0
    eta = 0.0
    for muon in event.muons:
        if muon.pt > maximum:
            maximum = muon.pt
            eta = muon.eta
            found = 1
    if found > 0:
        fill(eta)
";

    pub const PTSUM_PAIRS: &str = "\
for event in dataset:
    n = len(event.muons)
    for i in range(n):
        for j in range(i + 1, n):
            m1 = event.muons[i]
            m2 = event.muons[j]
            fill(m1.pt + m2.pt)
";

    pub const MASS_PAIRS: &str = "\
for event in dataset:
    n = len(event.muons)
    for i in range(n):
        for j in range(i + 1, n):
            m1 = event.muons[i]
            m2 = event.muons[j]
            mass = sqrt(2 * m1.pt * m2.pt * (cosh(m1.eta - m2.eta) - cos(m1.phi - m2.phi)))
            fill(mass)
";

    /// Table 1's payload (fusable: one total loop over one list).
    pub const JET_PT: &str = "\
for event in dataset:
    for jet in event.jets:
        fill(jet.pt)
";

    /// Same flat fill over muons, for the DY dataset.
    pub const MUON_PT: &str = "\
for event in dataset:
    for muon in event.muons:
        fill(muon.pt)
";
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::{generate_drellyan, generate_ttbar};
    use crate::engine::{columnar_exec, QueryKind};

    fn assert_hists_eq(a: &H1, b: &H1, what: &str) {
        assert_eq!(a.total(), b.total(), "{what}: totals");
        let diff: f64 = a.bins.iter().zip(&b.bins).map(|(x, y)| (x - y).abs()).sum();
        assert!(diff <= 4.0, "{what}: bins differ by {diff}");
    }

    /// The central §3 property: interpreter (objects) and transformed
    /// (arrays) programs produce identical histograms.
    #[test]
    fn transform_equals_interpreter_on_table3() {
        let cs = generate_drellyan(1200, 31);
        for (name, src, (lo, hi)) in [
            ("max_pt", table3::MAX_PT, (0.0, 128.0)),
            ("eta_best", table3::ETA_BEST, (-2.4, 2.4)),
            ("ptsum", table3::PTSUM_PAIRS, (0.0, 256.0)),
            ("mass", table3::MASS_PAIRS, (0.0, 128.0)),
        ] {
            let mut h_obj = H1::new(64, lo, hi);
            run_object_view(src, &cs, &mut h_obj).unwrap();
            let mut h_flat = H1::new(64, lo, hi);
            run_transformed(src, &cs, &mut h_flat).unwrap();
            assert_eq!(h_obj.bins, h_flat.bins, "{name}");
            assert_eq!(h_obj.total(), h_flat.total(), "{name}");
        }
    }

    /// The transformed program must also match the hand-written columnar
    /// executor (the "what the compiler should have produced" check).
    /// Note: the query-language MAX_PT starts its maximum at 0.0 (as in the
    /// paper's pseudocode), identical in effect to -inf here because all
    /// generated pts are positive.
    #[test]
    fn transform_equals_handwritten_columnar() {
        let cs = generate_drellyan(1500, 32);
        let cases: [(&str, QueryKind); 4] = [
            (table3::MAX_PT, QueryKind::MaxPt),
            (table3::ETA_BEST, QueryKind::EtaBest),
            (table3::PTSUM_PAIRS, QueryKind::PtSumPairs),
            (table3::MASS_PAIRS, QueryKind::MassPairs),
        ];
        for (src, kind) in cases {
            let (lo, hi) = kind.default_binning();
            let mut h_lang = H1::new(64, lo, hi);
            run_transformed(src, &cs, &mut h_lang).unwrap();
            let mut h_hand = H1::new(64, lo, hi);
            columnar_exec::run(kind, &cs, "muons", &mut h_hand).unwrap();
            assert_hists_eq(&h_lang, &h_hand, kind.artifact());
        }
    }

    #[test]
    fn fusion_applies_to_total_loops_only() {
        let schema = crate::columnar::schema::jet_event_schema(5);
        let fused = compile(table3::JET_PT, &schema).unwrap();
        assert!(fused.fused.is_some(), "jet-pt fill should fuse");

        let dy = crate::columnar::schema::muon_event_schema();
        let not_fused = compile(table3::MAX_PT, &dy).unwrap();
        assert!(not_fused.fused.is_none(), "max-pt has per-event state");
    }

    #[test]
    fn fused_and_unfused_agree() {
        let cs = generate_ttbar(800, 5, 33);
        let prog = compile(table3::JET_PT, &cs.schema).unwrap();
        let mut h_fused = H1::new(64, 0.0, 256.0);
        flat::run(&prog, &cs, &mut h_fused).unwrap();
        let mut h_loop = H1::new(64, 0.0, 256.0);
        flat::run_unfused(&prog, &cs, &mut h_loop).unwrap();
        assert_eq!(h_fused.bins, h_loop.bins);
        assert_eq!(h_fused.total(), h_loop.total());
    }

    #[test]
    fn event_level_leaves_work() {
        let cs = generate_drellyan(500, 34);
        let src = "for event in dataset:\n    fill(event.met)\n";
        let mut h_obj = H1::new(32, 0.0, 100.0);
        run_object_view(src, &cs, &mut h_obj).unwrap();
        let mut h_flat = H1::new(32, 0.0, 100.0);
        run_transformed(src, &cs, &mut h_flat).unwrap();
        assert_eq!(h_obj.bins, h_flat.bins);
        assert_eq!(h_obj.total(), 500.0);
    }

    #[test]
    fn weighted_fills_work() {
        let cs = generate_drellyan(300, 35);
        let src = "for event in dataset:\n    fill(event.met, 2.0)\n";
        let mut h = H1::new(32, 0.0, 100.0);
        run_transformed(src, &cs, &mut h).unwrap();
        assert_eq!(h.total(), 600.0);
    }

    #[test]
    fn helpful_errors() {
        let cs = generate_drellyan(10, 36);
        let bad_attr = "for event in dataset:\n    for m in event.muons:\n        fill(m.bogus)\n";
        let err = run_transformed(bad_attr, &cs, &mut H1::new(4, 0.0, 1.0)).unwrap_err();
        assert!(err.contains("bogus"), "{err}");
        let bad_var = "for event in dataset:\n    fill(nope)\n";
        let err = run_transformed(bad_var, &cs, &mut H1::new(4, 0.0, 1.0)).unwrap_err();
        assert!(err.contains("nope"), "{err}");
        let bad_fn = "for event in dataset:\n    fill(tan(1))\n";
        let err = run_transformed(bad_fn, &cs, &mut H1::new(4, 0.0, 1.0)).unwrap_err();
        assert!(err.contains("tan"), "{err}");
    }

    #[test]
    fn cuts_with_boolean_logic() {
        let cs = generate_drellyan(2000, 37);
        let src = "\
for event in dataset:
    for muon in event.muons:
        if muon.pt > 20 and muon.eta < 1.0 and muon.eta > -1.0:
            fill(muon.pt)
";
        let mut h_obj = H1::new(64, 0.0, 128.0);
        run_object_view(src, &cs, &mut h_obj).unwrap();
        let mut h_flat = H1::new(64, 0.0, 128.0);
        run_transformed(src, &cs, &mut h_flat).unwrap();
        assert_eq!(h_obj.bins, h_flat.bins);
        assert!(h_obj.total() > 0.0);
    }
}
