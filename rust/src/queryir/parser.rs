//! Recursive-descent parser: tokens → `Program`.

use super::ast::{BinOp, CmpOp, Expr, Iter, Program, Stmt};
use super::lexer::{lex, Tok};

#[derive(Debug, Clone, PartialEq)]
pub struct ParseError(pub String);

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "parse error: {}", self.0)
    }
}

impl std::error::Error for ParseError {}

pub fn parse(src: &str) -> Result<Program, ParseError> {
    let toks = lex(src).map_err(|e| ParseError(e.to_string()))?;
    let mut p = Parser { toks, pos: 0 };
    p.program()
}

struct Parser {
    toks: Vec<Tok>,
    pos: usize,
}

impl Parser {
    fn peek(&self) -> &Tok {
        self.toks.get(self.pos).unwrap_or(&Tok::Eof)
    }

    fn bump(&mut self) -> Tok {
        let t = self.peek().clone();
        self.pos += 1;
        t
    }

    fn expect(&mut self, t: &Tok) -> Result<(), ParseError> {
        if self.peek() == t {
            self.pos += 1;
            Ok(())
        } else {
            Err(ParseError(format!("expected {t:?}, found {:?}", self.peek())))
        }
    }

    fn ident(&mut self) -> Result<String, ParseError> {
        match self.bump() {
            Tok::Ident(s) => Ok(s),
            other => Err(ParseError(format!("expected identifier, found {other:?}"))),
        }
    }

    fn program(&mut self) -> Result<Program, ParseError> {
        // `for <event> in dataset:` INDENT body DEDENT EOF
        self.expect(&Tok::For)?;
        let event_var = self.ident()?;
        self.expect(&Tok::In)?;
        let ds = self.ident()?;
        if ds != "dataset" {
            return Err(ParseError(format!(
                "top-level loop must be over 'dataset', found '{ds}'"
            )));
        }
        self.expect(&Tok::Colon)?;
        self.expect(&Tok::Newline)?;
        let body = self.block()?;
        match self.peek() {
            Tok::Eof => Ok(Program { event_var, body }),
            other => Err(ParseError(format!(
                "unexpected {other:?} after the event loop (only one top-level loop allowed)"
            ))),
        }
    }

    fn block(&mut self) -> Result<Vec<Stmt>, ParseError> {
        self.expect(&Tok::Indent)?;
        let mut stmts = Vec::new();
        loop {
            match self.peek() {
                Tok::Dedent => {
                    self.pos += 1;
                    return Ok(stmts);
                }
                Tok::Eof => return Ok(stmts),
                _ => stmts.push(self.statement()?),
            }
        }
    }

    fn statement(&mut self) -> Result<Stmt, ParseError> {
        match self.peek().clone() {
            Tok::For => {
                self.pos += 1;
                let var = self.ident()?;
                self.expect(&Tok::In)?;
                let iter = self.iter_domain()?;
                self.expect(&Tok::Colon)?;
                self.expect(&Tok::Newline)?;
                let body = self.block()?;
                Ok(Stmt::For { var, iter, body })
            }
            Tok::If => {
                self.pos += 1;
                self.if_tail()
            }
            Tok::Ident(name) => {
                // `fill(...)` / `fill2(...)` / `profile(...)` /
                // `fill_vars(...)` or assignment.
                if name == "fill" {
                    self.pos += 1;
                    self.expect(&Tok::LParen)?;
                    let e = self.expr()?;
                    let w = if self.peek() == &Tok::Comma {
                        self.pos += 1;
                        Some(self.expr()?)
                    } else {
                        None
                    };
                    self.expect(&Tok::RParen)?;
                    self.expect(&Tok::Newline)?;
                    Ok(Stmt::Fill(e, w))
                } else if name == "fill2" || name == "profile" {
                    self.pos += 1;
                    self.expect(&Tok::LParen)?;
                    let x = self.expr()?;
                    self.expect(&Tok::Comma)?;
                    let y = self.expr()?;
                    let w = if self.peek() == &Tok::Comma {
                        self.pos += 1;
                        Some(self.expr()?)
                    } else {
                        None
                    };
                    self.expect(&Tok::RParen)?;
                    self.expect(&Tok::Newline)?;
                    if name == "fill2" {
                        Ok(Stmt::Fill2(x, y, w))
                    } else {
                        Ok(Stmt::FillProf(x, y, w))
                    }
                } else if name == "fill_vars" {
                    self.pos += 1;
                    self.expect(&Tok::LParen)?;
                    let x = self.expr()?;
                    let mut weights = Vec::new();
                    while self.peek() == &Tok::Comma {
                        self.pos += 1;
                        weights.push(self.expr()?);
                    }
                    self.expect(&Tok::RParen)?;
                    self.expect(&Tok::Newline)?;
                    if weights.is_empty() {
                        return Err(ParseError(
                            "fill_vars needs at least one weight variation".into(),
                        ));
                    }
                    Ok(Stmt::FillVars(x, weights))
                } else {
                    self.pos += 1;
                    self.expect(&Tok::Assign)?;
                    let e = self.expr()?;
                    self.expect(&Tok::Newline)?;
                    Ok(Stmt::Assign(name, e))
                }
            }
            other => Err(ParseError(format!("unexpected {other:?} at statement start"))),
        }
    }

    fn if_tail(&mut self) -> Result<Stmt, ParseError> {
        let cond = self.expr()?;
        self.expect(&Tok::Colon)?;
        self.expect(&Tok::Newline)?;
        let then = self.block()?;
        let els = match self.peek() {
            Tok::Else => {
                self.pos += 1;
                self.expect(&Tok::Colon)?;
                self.expect(&Tok::Newline)?;
                self.block()?
            }
            Tok::Elif => {
                self.pos += 1;
                vec![self.if_tail()?]
            }
            _ => Vec::new(),
        };
        Ok(Stmt::If { cond, then, els })
    }

    fn iter_domain(&mut self) -> Result<Iter, ParseError> {
        // `range(...)` or a list expression.
        if let Tok::Ident(name) = self.peek().clone() {
            if name == "range" {
                self.pos += 1;
                self.expect(&Tok::LParen)?;
                let first = self.expr()?;
                let iter = if self.peek() == &Tok::Comma {
                    self.pos += 1;
                    let second = self.expr()?;
                    Iter::Range(Some(first), second)
                } else {
                    Iter::Range(None, first)
                };
                self.expect(&Tok::RParen)?;
                return Ok(iter);
            }
            if name == "dataset" {
                self.pos += 1;
                return Ok(Iter::Dataset);
            }
        }
        Ok(Iter::List(self.expr()?))
    }

    // Expression precedence: or < and < not < cmp < add < mul < unary < postfix.
    fn expr(&mut self) -> Result<Expr, ParseError> {
        let mut lhs = self.and_expr()?;
        while self.peek() == &Tok::Or {
            self.pos += 1;
            let rhs = self.and_expr()?;
            lhs = Expr::Or(Box::new(lhs), Box::new(rhs));
        }
        Ok(lhs)
    }

    fn and_expr(&mut self) -> Result<Expr, ParseError> {
        let mut lhs = self.not_expr()?;
        while self.peek() == &Tok::And {
            self.pos += 1;
            let rhs = self.not_expr()?;
            lhs = Expr::And(Box::new(lhs), Box::new(rhs));
        }
        Ok(lhs)
    }

    fn not_expr(&mut self) -> Result<Expr, ParseError> {
        if self.peek() == &Tok::Not {
            self.pos += 1;
            Ok(Expr::Not(Box::new(self.not_expr()?)))
        } else {
            self.cmp_expr()
        }
    }

    fn cmp_expr(&mut self) -> Result<Expr, ParseError> {
        let lhs = self.add_expr()?;
        let op = match self.peek() {
            Tok::Lt => CmpOp::Lt,
            Tok::Le => CmpOp::Le,
            Tok::Gt => CmpOp::Gt,
            Tok::Ge => CmpOp::Ge,
            Tok::EqEq => CmpOp::Eq,
            Tok::Ne => CmpOp::Ne,
            _ => return Ok(lhs),
        };
        self.pos += 1;
        let rhs = self.add_expr()?;
        Ok(Expr::Cmp(op, Box::new(lhs), Box::new(rhs)))
    }

    fn add_expr(&mut self) -> Result<Expr, ParseError> {
        let mut lhs = self.mul_expr()?;
        loop {
            let op = match self.peek() {
                Tok::Plus => BinOp::Add,
                Tok::Minus => BinOp::Sub,
                _ => return Ok(lhs),
            };
            self.pos += 1;
            let rhs = self.mul_expr()?;
            lhs = Expr::Bin(op, Box::new(lhs), Box::new(rhs));
        }
    }

    fn mul_expr(&mut self) -> Result<Expr, ParseError> {
        let mut lhs = self.unary_expr()?;
        loop {
            let op = match self.peek() {
                Tok::Star => BinOp::Mul,
                Tok::Slash => BinOp::Div,
                _ => return Ok(lhs),
            };
            self.pos += 1;
            let rhs = self.unary_expr()?;
            lhs = Expr::Bin(op, Box::new(lhs), Box::new(rhs));
        }
    }

    fn unary_expr(&mut self) -> Result<Expr, ParseError> {
        if self.peek() == &Tok::Minus {
            self.pos += 1;
            Ok(Expr::Neg(Box::new(self.unary_expr()?)))
        } else {
            self.postfix_expr()
        }
    }

    fn postfix_expr(&mut self) -> Result<Expr, ParseError> {
        let mut e = self.atom()?;
        loop {
            match self.peek() {
                Tok::Dot => {
                    self.pos += 1;
                    let name = self.ident()?;
                    e = Expr::Attr(Box::new(e), name);
                }
                Tok::LBracket => {
                    self.pos += 1;
                    let idx = self.expr()?;
                    self.expect(&Tok::RBracket)?;
                    e = Expr::Index(Box::new(e), Box::new(idx));
                }
                _ => return Ok(e),
            }
        }
    }

    fn atom(&mut self) -> Result<Expr, ParseError> {
        match self.bump() {
            Tok::Num(n) => Ok(Expr::Num(n)),
            Tok::LParen => {
                let e = self.expr()?;
                self.expect(&Tok::RParen)?;
                Ok(e)
            }
            Tok::Ident(name) => {
                if self.peek() == &Tok::LParen {
                    self.pos += 1;
                    let mut args = Vec::new();
                    if self.peek() != &Tok::RParen {
                        loop {
                            args.push(self.expr()?);
                            if self.peek() == &Tok::Comma {
                                self.pos += 1;
                            } else {
                                break;
                            }
                        }
                    }
                    self.expect(&Tok::RParen)?;
                    Ok(Expr::Call(name, args))
                } else {
                    Ok(Expr::Var(name))
                }
            }
            other => Err(ParseError(format!("unexpected {other:?} in expression"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_max_pt() {
        let src = "\
for event in dataset:
    maximum = 0.0
    n = len(event.muons)
    for muon in event.muons:
        if muon.pt > maximum:
            maximum = muon.pt
    if n > 0:
        fill(maximum)
";
        let p = parse(src).unwrap();
        assert_eq!(p.event_var, "event");
        assert_eq!(p.body.len(), 4);
        match &p.body[2] {
            Stmt::For { var, iter, body } => {
                assert_eq!(var, "muon");
                assert_eq!(
                    iter,
                    &Iter::List(Expr::Attr(
                        Box::new(Expr::Var("event".into())),
                        "muons".into()
                    ))
                );
                assert_eq!(body.len(), 1);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn parses_pair_loop() {
        let src = "\
for event in dataset:
    n = len(event.muons)
    for i in range(n):
        for j in range(i + 1, n):
            m1 = event.muons[i]
            m2 = event.muons[j]
            fill(m1.pt + m2.pt)
";
        let p = parse(src).unwrap();
        match &p.body[1] {
            Stmt::For { iter: Iter::Range(None, _), body, .. } => match &body[0] {
                Stmt::For { iter: Iter::Range(Some(_), _), body, .. } => {
                    assert_eq!(body.len(), 3);
                }
                other => panic!("{other:?}"),
            },
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn precedence() {
        let src = "for e in dataset:\n    x = 1 + 2 * 3 - 4 / 2\n";
        let p = parse(src).unwrap();
        match &p.body[0] {
            Stmt::Assign(_, e) => {
                // (1 + (2*3)) - (4/2)
                match e {
                    Expr::Bin(BinOp::Sub, l, _) => match &**l {
                        Expr::Bin(BinOp::Add, _, r) => {
                            assert!(matches!(&**r, Expr::Bin(BinOp::Mul, _, _)))
                        }
                        other => panic!("{other:?}"),
                    },
                    other => panic!("{other:?}"),
                }
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn elif_and_bool_ops() {
        let src = "\
for e in dataset:
    if x > 1 and not y < 2:
        fill(1)
    elif x < 0 or y == 3:
        fill(2)
    else:
        fill(3)
";
        let p = parse(src).unwrap();
        match &p.body[0] {
            Stmt::If { cond: Expr::And(_, _), els, .. } => {
                assert_eq!(els.len(), 1);
                assert!(matches!(&els[0], Stmt::If { .. }));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn rejects_non_dataset_top_loop() {
        assert!(parse("for e in events:\n    fill(1)\n").is_err());
        assert!(parse("x = 1\n").is_err());
    }

    #[test]
    fn weighted_fill() {
        let p = parse("for e in dataset:\n    fill(e.met, 2.0)\n").unwrap();
        assert!(matches!(&p.body[0], Stmt::Fill(_, Some(_))));
    }

    #[test]
    fn agc_fill_forms() {
        let p = parse(
            "for e in dataset:\n    fill2(e.met, e.ht)\n    profile(e.met, e.ht, 2.0)\n    \
             fill_vars(e.met, 1.0, 0.9, 1.1)\n",
        )
        .unwrap();
        assert!(matches!(&p.body[0], Stmt::Fill2(_, _, None)));
        assert!(matches!(&p.body[1], Stmt::FillProf(_, _, Some(_))));
        match &p.body[2] {
            Stmt::FillVars(_, ws) => assert_eq!(ws.len(), 3),
            other => panic!("{other:?}"),
        }
        assert!(parse("for e in dataset:\n    fill_vars(e.met)\n").is_err());
        assert!(parse("for e in dataset:\n    fill2(e.met)\n").is_err());
    }
}
