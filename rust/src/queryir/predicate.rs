//! Predicate analysis: extract interval constraints from a validated
//! tape's `if` cuts and evaluate them against zone maps.
//!
//! The analyzable shape is the fused single-list body (`try_fuse`'s output
//! — the shape every flat cut query takes): a tree of `if` cuts around
//! `Fill` statements. Each fill site's effective mask is the conjunction of
//! its enclosing cut conditions, with `else` branches contributing the
//! negated condition — exactly the masks the chunked mask-and-fill kernel
//! materializes at run time. Here the same masks are evaluated *symbolically*
//! over a zone's column statistics ([`crate::index`]) instead of over
//! items, yielding a three-valued verdict per mask and one
//! [`ZoneDecision`] per zone:
//!
//!   * **Skip** — every mask is provably false for every item of the zone:
//!     no fill can fire, the zone contributes nothing, don't touch it;
//!   * **TakeAll** — every mask is provably true: the masks can be dropped
//!     and the unmasked batch kernel runs (bit-identical, since a mask
//!     that is 1 everywhere selects every value unchanged);
//!   * **Scan** — the statistics cannot decide; run the masked kernel.
//!
//! Two granularities are analyzable:
//!
//!   * **item-level** — the fused single-list body (`try_fuse`'s output):
//!     masks range over item columns, zones are item chunks;
//!   * **event-level** — loop-free per-event bodies (assignments inlined
//!     by `transform::inline_event_body`): masks range over event leaves
//!     (`event.met`) and list lengths (`len(event.muons)`), and zones are
//!     **event** chunks — evaluated against the per-event statistics the
//!     zone maps store for event columns and the synthetic per-list
//!     length column ([`crate::index::len_stats_path`]).
//!
//! Soundness rests on the interval arithmetic being an over-approximation
//! (see `index::interval`): `Tri::True`/`Tri::False` are proofs about every
//! item, NaN semantics included (a NaN fails every ordered comparison on
//! both the analysis and execution sides). Programs outside both shapes —
//! per-event accumulation loops, pair loops — simply yield no predicate
//! and are never pruned, and an unresolvable leaf (an indexed item load in
//! an event cut, a column missing from the map) degrades to `TOP`, never a
//! wrong claim.

use super::ast::CmpOp;
use super::transform::{self, CExpr, CStmt, FlatProgram};
use crate::index::{len_stats_path, Interval, Tri, ZoneMap};

/// What zone-map evaluation decided for one zone (partition or chunk).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ZoneDecision {
    /// No fill of the program can fire on any item of the zone.
    Skip,
    /// Every fill fires on every (non-NaN-valued) item: cut masks can be
    /// dropped.
    TakeAll,
    /// Statistics cannot decide; the zone runs the masked kernel.
    Scan,
}

/// Which zones the predicate's masks range over.
#[derive(Clone, Copy, Debug)]
enum Gran {
    /// Fused single-list body; `slot` holds the loop's item index.
    Items { slot: usize },
    /// Loop-free per-event body (assignments inlined).
    Events,
}

/// A statistics leaf a mask refers to.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
enum ColRef {
    /// Item column (by `item_cols` index).
    Item(usize),
    /// Event-level column (by `event_cols` index).
    Event(usize),
    /// Per-event length of a list (by `lists` index).
    Len(usize),
}

/// The cut structure of an analyzable body, ready for zone-map evaluation:
/// one effective mask per fill site (`None` = unconditional fill), over
/// the item columns (item granularity) or the event leaves and list
/// lengths (event granularity).
#[derive(Clone, Debug)]
pub struct CutPredicate {
    gran: Gran,
    /// Per fill site: the conjunction of enclosing cuts (else-negated).
    masks: Vec<Option<CExpr>>,
    /// Leaf paths of the program's item columns, in `col` order — the
    /// names zone-map lookups resolve against.
    item_cols: Vec<String>,
    /// Leaf paths of the program's event columns, in `col` order.
    event_cols: Vec<String>,
    /// List paths, in list-id order (length statistics resolve through
    /// [`len_stats_path`]).
    lists: Vec<String>,
}

/// Extract the cut predicate of a program, if it has an analyzable shape:
/// the fused single-list body (item granularity) or a loop-free per-event
/// body (event granularity).
pub fn extract(prog: &FlatProgram) -> Option<CutPredicate> {
    let (gran, masks) = if let Some(fused) = prog.fused.as_ref() {
        let [CStmt::LoopRange { slot, body, .. }] = &fused[..] else {
            return None;
        };
        let mut masks = Vec::new();
        collect_masks(body, None, &mut masks)?;
        (Gran::Items { slot: *slot }, masks)
    } else {
        let body = transform::inline_event_body(&prog.body)?;
        // Indexed item loads anywhere in the body refuse event pruning: a
        // Skip verdict would suppress loads the unindexed scalar scan
        // performs even when every cut is false (an inlined assignment's
        // load), changing out-of-bounds *error* behavior between indexed
        // and unindexed runs. Pure `event.*`/`len()` bodies — the shapes
        // event pruning exists for — are unaffected.
        if event_body_loads_items(&body) {
            return None;
        }
        let mut masks = Vec::new();
        collect_masks(&body, None, &mut masks)?;
        (Gran::Events, masks)
    };
    if masks.is_empty() {
        return None;
    }
    Some(CutPredicate {
        gran,
        masks,
        item_cols: prog.item_cols.clone(),
        event_cols: prog.event_cols.clone(),
        lists: prog.lists.clone(),
    })
}

/// Does any expression of an inlined event body load an item column?
fn event_body_loads_items(stmts: &[CStmt]) -> bool {
    stmts.iter().any(|s| match s {
        CStmt::Fill { expr, weight } => {
            transform::contains_item_load(expr)
                || weight.as_ref().is_some_and(transform::contains_item_load)
        }
        CStmt::If { cond, then, els } => {
            transform::contains_item_load(cond)
                || event_body_loads_items(then)
                || event_body_loads_items(els)
        }
        _ => false,
    })
}

/// Walk a fused statement block under an enclosing mask, recording each
/// fill site's effective mask. Mirrors the chunked kernel's mask builder:
/// nested `if`s conjoin, `else` branches negate.
fn collect_masks(
    stmts: &[CStmt],
    mask: Option<&CExpr>,
    out: &mut Vec<Option<CExpr>>,
) -> Option<()> {
    for s in stmts {
        match s {
            CStmt::Fill { .. } => out.push(mask.cloned()),
            CStmt::If { cond, then, els } => {
                collect_masks(then, Some(&conjoin(mask, cond)), out)?;
                if !els.is_empty() {
                    let neg = CExpr::Not(Box::new(cond.clone()));
                    collect_masks(els, Some(&conjoin(mask, &neg)), out)?;
                }
            }
            // `try_fuse` admits only Fill and If; anything else means the
            // body is not the analyzable shape.
            _ => return None,
        }
    }
    Some(())
}

fn conjoin(mask: Option<&CExpr>, cond: &CExpr) -> CExpr {
    match mask {
        Some(m) => CExpr::And(Box::new(m.clone()), Box::new(cond.clone())),
        None => cond.clone(),
    }
}

impl CutPredicate {
    /// Is this an event-granularity predicate (zones = event chunks)?
    pub fn is_event_level(&self) -> bool {
        matches!(self.gran, Gran::Events)
    }

    /// EXPLAIN support: one entry per fill site, in body order — the
    /// conjunction of enclosing cuts gating it, or `unconditional`.
    /// Item/event leaves are named via the program's column bindings.
    pub fn describe_masks(&self) -> Vec<String> {
        let name = |cols: &[String], c: usize| {
            cols.get(c).cloned().unwrap_or_else(|| format!("col{c}"))
        };
        self.masks
            .iter()
            .map(|m| match m {
                None => "unconditional".to_string(),
                Some(e) => {
                    let mut s = format!("{e:?}");
                    // Annotate which leaves the cut reads so the Debug
                    // rendering's column indices are resolvable.
                    let mut refs: Vec<ColRef> = Vec::new();
                    referenced_refs(e, self.gran, &mut refs);
                    refs.sort_unstable();
                    refs.dedup();
                    let leaves: Vec<String> = refs
                        .iter()
                        .map(|r| match r {
                            ColRef::Item(c) => name(&self.item_cols, *c),
                            ColRef::Event(c) => name(&self.event_cols, *c),
                            ColRef::Len(l) => format!("len(list{l})"),
                        })
                        .collect();
                    if s.len() > 120 {
                        s.truncate(117);
                        s.push_str("...");
                    }
                    format!("{s} [reads: {}]", leaves.join(", "))
                }
            })
            .collect()
    }

    /// Classify one zone given a value interval per statistics leaf.
    fn classify_ref(&self, col: &dyn Fn(ColRef) -> Interval) -> ZoneDecision {
        let mut any_may_fire = false;
        let mut all_fire = true;
        for m in &self.masks {
            match m {
                None => any_may_fire = true, // unconditional fill
                Some(e) => match truth(e, self.gran, col) {
                    Tri::True => any_may_fire = true,
                    Tri::False => all_fire = false,
                    Tri::Unknown => {
                        any_may_fire = true;
                        all_fire = false;
                    }
                },
            }
        }
        if !any_may_fire {
            ZoneDecision::Skip
        } else if all_fire {
            ZoneDecision::TakeAll
        } else {
            ZoneDecision::Scan
        }
    }

    /// Classify one zone given a value interval per **item** column (the
    /// item-granularity entry point tests and embedders use; event and
    /// length leaves come out `TOP`).
    pub fn classify_with(&self, col: &dyn Fn(usize) -> Interval) -> ZoneDecision {
        self.classify_ref(&|r| match r {
            ColRef::Item(c) => col(c),
            ColRef::Event(_) | ColRef::Len(_) => Interval::TOP,
        })
    }

    /// Classify a whole partition against its zone map.
    pub fn classify_partition(&self, zm: &ZoneMap) -> ZoneDecision {
        self.classify_ref(&|r| self.lookup(zm, r))
    }

    /// Classify every chunk of a partition — item chunks for item
    /// granularity, event chunks for event granularity. Returns `None`
    /// when the masks reference no statistics or the referenced columns
    /// disagree on the chunk grid (inconsistent map) — callers then fall
    /// back to scanning.
    pub fn classify_chunks(&self, zm: &ZoneMap) -> Option<Vec<ZoneDecision>> {
        let mut refs: Vec<ColRef> = Vec::new();
        for m in self.masks.iter().flatten() {
            referenced_refs(m, self.gran, &mut refs);
        }
        refs.sort_unstable();
        refs.dedup();
        // Resolve every referenced leaf's statistics once; the per-chunk
        // pass below then indexes the resolved zones directly instead of
        // re-deriving string keys and map lookups per (chunk, leaf) pair.
        let mut zones: Vec<(ColRef, &crate::index::ColumnZones)> = Vec::with_capacity(refs.len());
        let mut n_chunks: Option<usize> = None;
        for &r in &refs {
            let z = zm.column(&self.ref_path(r)?)?;
            match n_chunks {
                Some(n) if n != z.chunks.len() => return None,
                _ => n_chunks = Some(z.chunks.len()),
            }
            zones.push((r, z));
        }
        let n = n_chunks?;
        let decisions = (0..n)
            .map(|i| {
                self.classify_ref(&|r| match zones.iter().find(|(rr, _)| *rr == r) {
                    Some((_, z)) => z.chunks[i].interval(),
                    None => Interval::TOP,
                })
            })
            .collect();
        Some(decisions)
    }

    /// The zone-map key a statistics leaf resolves to.
    fn ref_path(&self, r: ColRef) -> Option<String> {
        match r {
            ColRef::Item(c) => self.item_cols.get(c).cloned(),
            ColRef::Event(c) => self.event_cols.get(c).cloned(),
            ColRef::Len(l) => self.lists.get(l).map(|p| len_stats_path(p)),
        }
    }

    /// The interval a zone map proves for one statistics leaf over the
    /// whole partition. Anything unresolvable is `TOP` — never a wrong
    /// claim.
    fn lookup(&self, zm: &ZoneMap, r: ColRef) -> Interval {
        let Some(path) = self.ref_path(r) else {
            return Interval::TOP;
        };
        let Some(z) = zm.column(&path) else {
            return Interval::TOP;
        };
        z.whole.interval()
    }
}

/// Statistics leaves referenced anywhere in a mask, at this granularity.
fn referenced_refs(e: &CExpr, gran: Gran, out: &mut Vec<ColRef>) {
    match e {
        CExpr::LoadItem { col, idx } => {
            if let Gran::Items { .. } = gran {
                out.push(ColRef::Item(*col));
            }
            referenced_refs(idx, gran, out);
        }
        CExpr::LoadEvent { col } => {
            if let Gran::Events = gran {
                out.push(ColRef::Event(*col));
            }
        }
        CExpr::ListLen { list } => {
            if let Gran::Events = gran {
                out.push(ColRef::Len(*list));
            }
        }
        CExpr::Bin(_, l, r) | CExpr::Cmp(_, l, r) | CExpr::And(l, r) | CExpr::Or(l, r) => {
            referenced_refs(l, gran, out);
            referenced_refs(r, gran, out);
        }
        CExpr::Not(x) | CExpr::Neg(x) => referenced_refs(x, gran, out),
        CExpr::Call(_, args) => {
            for a in args {
                referenced_refs(a, gran, out);
            }
        }
        CExpr::Const(_) | CExpr::Slot(_) => {}
    }
}

/// Three-valued truthiness of a condition over a zone, matching the
/// kernel's rule (`cond != 0.0`; NaN conditions are truthy).
fn truth(e: &CExpr, gran: Gran, col: &dyn Fn(ColRef) -> Interval) -> Tri {
    match e {
        CExpr::Cmp(op, l, r) => {
            let a = ival(l, gran, col);
            let b = ival(r, gran, col);
            match op {
                CmpOp::Lt => a.lt(b),
                CmpOp::Le => a.le(b),
                CmpOp::Gt => a.gt(b),
                CmpOp::Ge => a.ge(b),
                CmpOp::Eq => a.eq(b),
                CmpOp::Ne => a.ne(b),
            }
        }
        CExpr::And(l, r) => truth(l, gran, col).and(truth(r, gran, col)),
        CExpr::Or(l, r) => truth(l, gran, col).or(truth(r, gran, col)),
        CExpr::Not(x) => truth(x, gran, col).not(),
        other => ival(other, gran, col).truthy(),
    }
}

/// Interval of an expression's values over a zone.
fn ival(e: &CExpr, gran: Gran, col: &dyn Fn(ColRef) -> Interval) -> Interval {
    match e {
        CExpr::Const(c) => Interval::point(*c),
        // The fused loop index: a non-negative finite integer. (Event
        // masks are slot-free after inlining; stay conservative if a slot
        // ever appears.)
        CExpr::Slot(s) => match gran {
            Gran::Items { slot } if *s == slot => Interval {
                lo: 0.0,
                hi: f64::INFINITY,
                nan: false,
            },
            _ => Interval::TOP,
        },
        CExpr::LoadEvent { col: c } => match gran {
            // Event zones carry per-event statistics of event leaves.
            Gran::Events => col(ColRef::Event(*c)),
            // An event leaf inside a fused body cannot occur (`try_fuse`
            // refuses), but stay conservative.
            Gran::Items { .. } => Interval::TOP,
        },
        CExpr::ListLen { list } => match gran {
            // Event zones carry per-event length statistics (the
            // synthetic `len_stats_path` column).
            Gran::Events => col(ColRef::Len(*list)),
            Gran::Items { .. } => Interval::TOP,
        },
        CExpr::LoadItem { col: c, idx } => match (gran, idx.as_ref()) {
            // Only loads at the loop index are covered by the zone's
            // statistics; a computed index may read another zone (and an
            // indexed load in an event mask reads across the event grid).
            (Gran::Items { slot }, CExpr::Slot(s)) if *s == slot => col(ColRef::Item(*c)),
            _ => Interval::TOP,
        },
        CExpr::Bin(op, l, r) => {
            let a = ival(l, gran, col);
            let b = ival(r, gran, col);
            match op {
                super::ast::BinOp::Add => a.add(b),
                super::ast::BinOp::Sub => a.sub(b),
                super::ast::BinOp::Mul => a.mul(b),
                super::ast::BinOp::Div => a.div(b),
            }
        }
        // Boolean-valued subexpressions produce exactly 0.0 or 1.0; refine
        // through their three-valued truth.
        CExpr::Cmp(..) | CExpr::And(..) | CExpr::Or(..) | CExpr::Not(..) => {
            match truth(e, gran, col) {
                Tri::True => Interval::point(1.0),
                Tri::False => Interval::point(0.0),
                Tri::Unknown => Interval {
                    lo: 0.0,
                    hi: 1.0,
                    nan: false,
                },
            }
        }
        CExpr::Neg(x) => ival(x, gran, col).neg(),
        CExpr::Call(name, args) => {
            let one = |f: fn(Interval) -> Interval| f(ival(&args[0], gran, col));
            match (*name, args.len()) {
                ("sqrt", 1) => one(Interval::sqrt),
                ("abs", 1) => one(Interval::abs),
                ("exp", 1) => one(Interval::exp),
                ("log", 1) => one(Interval::ln),
                ("sin", 1) | ("cos", 1) => one(Interval::sin_cos),
                ("sinh", 1) => one(Interval::sinh),
                ("cosh", 1) => one(Interval::cosh),
                ("min", 2) => ival(&args[0], gran, col).imin(ival(&args[1], gran, col)),
                ("max", 2) => ival(&args[0], gran, col).imax(ival(&args[1], gran, col)),
                // __list_base / __list_total and anything unknown.
                _ => Interval::TOP,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::columnar::schema::muon_event_schema;
    use crate::index::ColumnStats;
    use crate::queryir;

    fn pred(src: &str) -> CutPredicate {
        let prog = queryir::compile(src, &muon_event_schema()).unwrap();
        extract(&prog).expect("program should yield a predicate")
    }

    /// A stats lookup with fixed per-column intervals, `col 0 = muons.pt`
    /// in the sources below.
    fn with_pt(lo: f64, hi: f64, nan: bool) -> impl Fn(usize) -> Interval {
        move |c| {
            if c == 0 {
                Interval { lo, hi, nan }
            } else {
                Interval::TOP
            }
        }
    }

    const CUT: &str = "\
for event in dataset:
    for muon in event.muons:
        if muon.pt > 25:
            fill(muon.pt)
";

    #[test]
    fn simple_cut_classifies_all_three_ways() {
        let p = pred(CUT);
        assert_eq!(p.classify_with(&with_pt(1.0, 10.0, false)), ZoneDecision::Skip);
        assert_eq!(p.classify_with(&with_pt(30.0, 90.0, false)), ZoneDecision::TakeAll);
        assert_eq!(p.classify_with(&with_pt(10.0, 90.0, false)), ZoneDecision::Scan);
        // The cut boundary itself is not provably passing.
        assert_eq!(p.classify_with(&with_pt(25.0, 90.0, false)), ZoneDecision::Scan);
    }

    #[test]
    fn nan_columns_block_take_all_but_not_skip() {
        let p = pred(CUT);
        // NaN items fail the cut on both analysis and execution sides.
        assert_eq!(p.classify_with(&with_pt(1.0, 10.0, true)), ZoneDecision::Skip);
        assert_eq!(p.classify_with(&with_pt(30.0, 90.0, true)), ZoneDecision::Scan);
    }

    #[test]
    fn else_branch_negation_prevents_skip() {
        let src = "\
for event in dataset:
    for muon in event.muons:
        if muon.pt > 25:
            fill(muon.pt)
        else:
            fill(muon.eta)
";
        let p = pred(src);
        // Some fill fires for every item whatever pt is, so the zone can
        // never Skip — but it can't TakeAll either: dropping *all* masks
        // would fire both branches on every item. One branch provably
        // dead still leaves the other's mask load-bearing: Scan.
        assert_eq!(p.classify_with(&with_pt(1.0, 10.0, false)), ZoneDecision::Scan);
        assert_eq!(p.classify_with(&with_pt(30.0, 90.0, false)), ZoneDecision::Scan);
        assert_eq!(p.classify_with(&with_pt(10.0, 90.0, false)), ZoneDecision::Scan);
    }

    #[test]
    fn nested_cuts_conjoin_and_unconditional_fills_prevent_skip() {
        let src = "\
for event in dataset:
    for muon in event.muons:
        if muon.pt > 25:
            if muon.pt < 50:
                fill(muon.pt)
";
        let p = pred(src);
        assert_eq!(p.classify_with(&with_pt(60.0, 90.0, false)), ZoneDecision::Skip);
        assert_eq!(p.classify_with(&with_pt(30.0, 40.0, false)), ZoneDecision::TakeAll);

        let src2 = "\
for event in dataset:
    for muon in event.muons:
        fill(muon.eta)
        if muon.pt > 25:
            fill(muon.pt)
";
        let p2 = pred(src2);
        assert_eq!(p2.classify_with(&with_pt(1.0, 10.0, false)), ZoneDecision::Scan);
        assert_eq!(p2.classify_with(&with_pt(30.0, 90.0, false)), ZoneDecision::TakeAll);
    }

    #[test]
    fn monotone_builtins_prune() {
        let src = "\
for event in dataset:
    for muon in event.muons:
        if sqrt(muon.pt) > 5:
            fill(muon.pt)
";
        let p = pred(src);
        // sqrt(pt) <= 4.9 < 5 for pt <= 24.
        assert_eq!(p.classify_with(&with_pt(1.0, 24.0, false)), ZoneDecision::Skip);
        assert_eq!(p.classify_with(&with_pt(26.0, 99.0, false)), ZoneDecision::TakeAll);
    }

    #[test]
    fn non_fused_programs_yield_no_predicate() {
        let schema = muon_event_schema();
        let max_pt = queryir::compile(queryir::table3::MAX_PT, &schema).unwrap();
        assert!(extract(&max_pt).is_none());
        let pairs = queryir::compile(queryir::table3::MASS_PAIRS, &schema).unwrap();
        assert!(extract(&pairs).is_none());
        // Unconditional flat fills do yield one (a single None mask): they
        // can be proven TakeAll but never skipped.
        let flat = queryir::compile(queryir::table3::MUON_PT, &schema).unwrap();
        let p = extract(&flat).unwrap();
        assert_eq!(p.classify_with(&|_| Interval::TOP), ZoneDecision::TakeAll);
    }

    #[test]
    fn chunk_classification_uses_per_chunk_stats() {
        use crate::columnar::arrays::{Array, ColumnSet};
        let mut cs = ColumnSet::empty(muon_event_schema());
        cs.n_events = 2;
        cs.offsets.insert("muons".into(), vec![0, 3, 6]);
        cs.leaves.insert(
            "muons.pt".into(),
            Array::F32(vec![1.0, 2.0, 3.0, 40.0, 50.0, 60.0]),
        );
        for path in ["muons.eta", "muons.phi"] {
            cs.leaves.insert(path.into(), Array::F32(vec![0.0; 6]));
        }
        cs.leaves
            .insert("muons.charge".into(), Array::I32(vec![1; 6]));
        cs.leaves.insert("met".into(), Array::F32(vec![0.0; 2]));
        let zm = crate::index::ZoneMap::build_with_chunk(&cs, 3);
        let p = pred(CUT);
        let d = p.classify_chunks(&zm).unwrap();
        assert_eq!(d, vec![ZoneDecision::Skip, ZoneDecision::TakeAll]);
        assert_eq!(p.classify_partition(&zm), ZoneDecision::Scan);
    }

    #[test]
    fn missing_columns_degrade_to_scan() {
        let p = pred(CUT);
        let zm = crate::index::ZoneMap {
            chunk_items: 4,
            columns: Default::default(),
        };
        assert_eq!(p.classify_partition(&zm), ZoneDecision::Scan);
        assert!(p.classify_chunks(&zm).is_none());
    }

    #[test]
    fn interval_eval_covers_boolean_subexpressions() {
        // `(pt > 10) + 1 > 1` is true exactly when the cut passes; the
        // boolean refinement keeps it decidable.
        let src = "\
for event in dataset:
    for muon in event.muons:
        if not muon.pt > 10:
            fill(muon.pt)
";
        let p = pred(src);
        assert_eq!(p.classify_with(&with_pt(20.0, 30.0, false)), ZoneDecision::Skip);
        assert_eq!(p.classify_with(&with_pt(1.0, 5.0, false)), ZoneDecision::TakeAll);
    }

    fn stats(lo: f64, hi: f64) -> ColumnStats {
        ColumnStats {
            min: lo,
            max: hi,
            has_nan: false,
            count: 4,
        }
    }

    /// A zone map with one chunk of event-granularity statistics.
    fn event_zone(met: (f64, f64), len: (f64, f64)) -> ZoneMap {
        let mut columns = std::collections::BTreeMap::new();
        columns.insert(
            "met".to_string(),
            crate::index::ColumnZones {
                whole: stats(met.0, met.1),
                chunks: vec![stats(met.0, met.1)],
            },
        );
        columns.insert(
            len_stats_path("muons"),
            crate::index::ColumnZones {
                whole: stats(len.0, len.1),
                chunks: vec![stats(len.0, len.1)],
            },
        );
        ZoneMap {
            chunk_items: 1024,
            columns,
        }
    }

    /// Event-level cuts — `event.met` and `len()` — extract an
    /// event-granularity predicate and classify against the event zones.
    #[test]
    fn event_level_cuts_classify_against_event_zones() {
        let src = "\
for event in dataset:
    if event.met > 25 and len(event.muons) >= 2:
        fill(event.met)
";
        let prog = queryir::compile(src, &muon_event_schema()).unwrap();
        let p = extract(&prog).unwrap();
        assert!(p.is_event_level());
        assert_eq!(
            p.classify_partition(&event_zone((0.0, 10.0), (0.0, 8.0))),
            ZoneDecision::Skip,
            "met too small everywhere"
        );
        assert_eq!(
            p.classify_partition(&event_zone((30.0, 90.0), (0.0, 8.0))),
            ZoneDecision::Scan,
            "some events may have < 2 muons"
        );
        assert_eq!(
            p.classify_partition(&event_zone((30.0, 90.0), (2.0, 8.0))),
            ZoneDecision::TakeAll
        );
        assert_eq!(
            p.classify_partition(&event_zone((30.0, 90.0), (0.0, 1.0))),
            ZoneDecision::Skip,
            "no event has 2 muons"
        );
        assert_eq!(
            p.classify_chunks(&event_zone((30.0, 90.0), (2.0, 8.0))).unwrap(),
            vec![ZoneDecision::TakeAll]
        );
    }

    /// Assignments inline into event predicates; bodies that load item
    /// columns yield no event predicate at all — a Skip verdict could
    /// suppress a load (and its out-of-bounds error) the unindexed scan
    /// performs unconditionally.
    #[test]
    fn event_predicate_assignments_and_item_loads() {
        let schema = muon_event_schema();
        let src = "\
for event in dataset:
    m = event.met
    if m > 25:
        fill(m)
";
        let p = extract(&queryir::compile(src, &schema).unwrap()).unwrap();
        assert!(p.is_event_level());
        assert_eq!(
            p.classify_partition(&event_zone((0.0, 10.0), (0.0, 8.0))),
            ZoneDecision::Skip
        );
        for src2 in [
            "for event in dataset:\n    if event.muons[0].pt > 10:\n        fill(event.met)\n",
            "for event in dataset:\n    x = event.muons[0].pt\n    \
             if event.met > 10:\n        fill(x)\n",
        ] {
            assert!(
                extract(&queryir::compile(src2, &schema).unwrap()).is_none(),
                "item-loading event bodies must not prune:\n{src2}"
            );
        }
    }

    /// Stats-derived intervals plug straight in.
    #[test]
    fn column_stats_drive_classification() {
        let mut s = ColumnStats::empty();
        for v in [30.0, 40.0, 55.0] {
            s.update(v);
        }
        let p = pred(CUT);
        let d = p.classify_with(&|c| {
            if c == 0 {
                s.interval()
            } else {
                Interval::TOP
            }
        });
        assert_eq!(d, ZoneDecision::TakeAll);
    }
}
