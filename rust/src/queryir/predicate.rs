//! Predicate analysis: extract interval constraints from a validated
//! tape's `if` cuts and evaluate them against zone maps.
//!
//! The analyzable shape is the fused single-list body (`try_fuse`'s output
//! — the shape every flat cut query takes): a tree of `if` cuts around
//! `Fill` statements. Each fill site's effective mask is the conjunction of
//! its enclosing cut conditions, with `else` branches contributing the
//! negated condition — exactly the masks the chunked mask-and-fill kernel
//! materializes at run time. Here the same masks are evaluated *symbolically*
//! over a zone's column statistics ([`crate::index`]) instead of over
//! items, yielding a three-valued verdict per mask and one
//! [`ZoneDecision`] per zone:
//!
//!   * **Skip** — every mask is provably false for every item of the zone:
//!     no fill can fire, the zone contributes nothing, don't touch it;
//!   * **TakeAll** — every mask is provably true: the masks can be dropped
//!     and the unmasked batch kernel runs (bit-identical, since a mask
//!     that is 1 everywhere selects every value unchanged);
//!   * **Scan** — the statistics cannot decide; run the masked kernel.
//!
//! Soundness rests on the interval arithmetic being an over-approximation
//! (see `index::interval`): `Tri::True`/`Tri::False` are proofs about every
//! item, NaN semantics included (a NaN fails every ordered comparison on
//! both the analysis and execution sides). Programs outside the fused shape
//! — per-event state, `len()` cuts, pair loops — simply yield no predicate
//! and are never pruned.

use super::ast::CmpOp;
use super::transform::{CExpr, CStmt, FlatProgram};
use crate::index::{Interval, Tri, ZoneMap};

/// What zone-map evaluation decided for one zone (partition or chunk).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ZoneDecision {
    /// No fill of the program can fire on any item of the zone.
    Skip,
    /// Every fill fires on every (non-NaN-valued) item: cut masks can be
    /// dropped.
    TakeAll,
    /// Statistics cannot decide; the zone runs the masked kernel.
    Scan,
}

/// The cut structure of a fused body, ready for zone-map evaluation: one
/// effective mask per fill site (`None` = unconditional fill), over the
/// item columns of the program.
#[derive(Clone, Debug)]
pub struct CutPredicate {
    /// Slot holding the fused loop's item index.
    slot: usize,
    /// Per fill site: the conjunction of enclosing cuts (else-negated).
    masks: Vec<Option<CExpr>>,
    /// Leaf paths of the program's item columns, in `col` order — the
    /// names zone-map lookups resolve against.
    item_cols: Vec<String>,
}

/// Extract the cut predicate of a program's fused body, if it has one.
pub fn extract(prog: &FlatProgram) -> Option<CutPredicate> {
    let fused = prog.fused.as_ref()?;
    let [CStmt::LoopRange { slot, body, .. }] = &fused[..] else {
        return None;
    };
    let mut masks = Vec::new();
    collect_masks(body, None, &mut masks)?;
    if masks.is_empty() {
        return None;
    }
    Some(CutPredicate {
        slot: *slot,
        masks,
        item_cols: prog.item_cols.clone(),
    })
}

/// Walk a fused statement block under an enclosing mask, recording each
/// fill site's effective mask. Mirrors the chunked kernel's mask builder:
/// nested `if`s conjoin, `else` branches negate.
fn collect_masks(
    stmts: &[CStmt],
    mask: Option<&CExpr>,
    out: &mut Vec<Option<CExpr>>,
) -> Option<()> {
    for s in stmts {
        match s {
            CStmt::Fill { .. } => out.push(mask.cloned()),
            CStmt::If { cond, then, els } => {
                collect_masks(then, Some(&conjoin(mask, cond)), out)?;
                if !els.is_empty() {
                    let neg = CExpr::Not(Box::new(cond.clone()));
                    collect_masks(els, Some(&conjoin(mask, &neg)), out)?;
                }
            }
            // `try_fuse` admits only Fill and If; anything else means the
            // body is not the analyzable shape.
            _ => return None,
        }
    }
    Some(())
}

fn conjoin(mask: Option<&CExpr>, cond: &CExpr) -> CExpr {
    match mask {
        Some(m) => CExpr::And(Box::new(m.clone()), Box::new(cond.clone())),
        None => cond.clone(),
    }
}

impl CutPredicate {
    /// Classify one zone given a value interval per item column.
    pub fn classify_with(&self, col: &dyn Fn(usize) -> Interval) -> ZoneDecision {
        let mut any_may_fire = false;
        let mut all_fire = true;
        for m in &self.masks {
            match m {
                None => any_may_fire = true, // unconditional fill
                Some(e) => match truth(e, self.slot, col) {
                    Tri::True => any_may_fire = true,
                    Tri::False => all_fire = false,
                    Tri::Unknown => {
                        any_may_fire = true;
                        all_fire = false;
                    }
                },
            }
        }
        if !any_may_fire {
            ZoneDecision::Skip
        } else if all_fire {
            ZoneDecision::TakeAll
        } else {
            ZoneDecision::Scan
        }
    }

    /// Classify a whole partition against its zone map.
    pub fn classify_partition(&self, zm: &ZoneMap) -> ZoneDecision {
        self.classify_with(&|c| self.lookup(zm, c, None))
    }

    /// Classify every chunk of a partition. Returns `None` when the masks
    /// reference no columns or the referenced columns disagree on the chunk
    /// grid (inconsistent map) — callers then fall back to scanning.
    pub fn classify_chunks(&self, zm: &ZoneMap) -> Option<Vec<ZoneDecision>> {
        let mut cols: Vec<usize> = Vec::new();
        for m in self.masks.iter().flatten() {
            referenced_cols(m, &mut cols);
        }
        cols.sort_unstable();
        cols.dedup();
        let mut n_chunks: Option<usize> = None;
        for &c in &cols {
            let z = zm.column(self.item_cols.get(c)?)?;
            match n_chunks {
                Some(n) if n != z.chunks.len() => return None,
                _ => n_chunks = Some(z.chunks.len()),
            }
        }
        let n = n_chunks?;
        let decisions = (0..n)
            .map(|i| self.classify_with(&|c| self.lookup(zm, c, Some(i))))
            .collect();
        Some(decisions)
    }

    /// The interval a zone map proves for item column `c` (whole partition
    /// or one chunk). Anything unresolvable is `TOP` — never a wrong claim.
    fn lookup(&self, zm: &ZoneMap, c: usize, chunk: Option<usize>) -> Interval {
        let Some(path) = self.item_cols.get(c) else {
            return Interval::TOP;
        };
        let Some(z) = zm.column(path) else {
            return Interval::TOP;
        };
        let stats = match chunk {
            None => &z.whole,
            Some(i) => match z.chunks.get(i) {
                Some(s) => s,
                None => return Interval::TOP,
            },
        };
        stats.interval()
    }
}

/// Item columns loaded (at the loop index) anywhere in an expression.
fn referenced_cols(e: &CExpr, out: &mut Vec<usize>) {
    match e {
        CExpr::LoadItem { col, idx } => {
            out.push(*col);
            referenced_cols(idx, out);
        }
        CExpr::Bin(_, l, r) | CExpr::Cmp(_, l, r) | CExpr::And(l, r) | CExpr::Or(l, r) => {
            referenced_cols(l, out);
            referenced_cols(r, out);
        }
        CExpr::Not(x) | CExpr::Neg(x) => referenced_cols(x, out),
        CExpr::Call(_, args) => {
            for a in args {
                referenced_cols(a, out);
            }
        }
        CExpr::Const(_) | CExpr::Slot(_) | CExpr::LoadEvent { .. } | CExpr::ListLen { .. } => {}
    }
}

/// Three-valued truthiness of a condition over a zone, matching the
/// kernel's rule (`cond != 0.0`; NaN conditions are truthy).
fn truth(e: &CExpr, slot: usize, col: &dyn Fn(usize) -> Interval) -> Tri {
    match e {
        CExpr::Cmp(op, l, r) => {
            let a = ival(l, slot, col);
            let b = ival(r, slot, col);
            match op {
                CmpOp::Lt => a.lt(b),
                CmpOp::Le => a.le(b),
                CmpOp::Gt => a.gt(b),
                CmpOp::Ge => a.ge(b),
                CmpOp::Eq => a.eq(b),
                CmpOp::Ne => a.ne(b),
            }
        }
        CExpr::And(l, r) => truth(l, slot, col).and(truth(r, slot, col)),
        CExpr::Or(l, r) => truth(l, slot, col).or(truth(r, slot, col)),
        CExpr::Not(x) => truth(x, slot, col).not(),
        other => ival(other, slot, col).truthy(),
    }
}

/// Interval of an expression's values over a zone.
fn ival(e: &CExpr, slot: usize, col: &dyn Fn(usize) -> Interval) -> Interval {
    match e {
        CExpr::Const(c) => Interval::point(*c),
        // The fused loop index: a non-negative finite integer.
        CExpr::Slot(s) if *s == slot => Interval {
            lo: 0.0,
            hi: f64::INFINITY,
            nan: false,
        },
        // Any other slot is per-event state; fused bodies have none, but
        // stay conservative if one ever appears.
        CExpr::Slot(_) | CExpr::LoadEvent { .. } | CExpr::ListLen { .. } => Interval::TOP,
        CExpr::LoadItem { col: c, idx } => match idx.as_ref() {
            // Only loads at the loop index are covered by the zone's
            // statistics; a computed index may read another zone.
            CExpr::Slot(s) if *s == slot => col(*c),
            _ => Interval::TOP,
        },
        CExpr::Bin(op, l, r) => {
            let a = ival(l, slot, col);
            let b = ival(r, slot, col);
            match op {
                super::ast::BinOp::Add => a.add(b),
                super::ast::BinOp::Sub => a.sub(b),
                super::ast::BinOp::Mul => a.mul(b),
                super::ast::BinOp::Div => a.div(b),
            }
        }
        // Boolean-valued subexpressions produce exactly 0.0 or 1.0; refine
        // through their three-valued truth.
        CExpr::Cmp(..) | CExpr::And(..) | CExpr::Or(..) | CExpr::Not(..) => {
            match truth(e, slot, col) {
                Tri::True => Interval::point(1.0),
                Tri::False => Interval::point(0.0),
                Tri::Unknown => Interval {
                    lo: 0.0,
                    hi: 1.0,
                    nan: false,
                },
            }
        }
        CExpr::Neg(x) => ival(x, slot, col).neg(),
        CExpr::Call(name, args) => {
            let one = |f: fn(Interval) -> Interval| f(ival(&args[0], slot, col));
            match (*name, args.len()) {
                ("sqrt", 1) => one(Interval::sqrt),
                ("abs", 1) => one(Interval::abs),
                ("exp", 1) => one(Interval::exp),
                ("log", 1) => one(Interval::ln),
                ("sin", 1) | ("cos", 1) => one(Interval::sin_cos),
                ("sinh", 1) => one(Interval::sinh),
                ("cosh", 1) => one(Interval::cosh),
                ("min", 2) => ival(&args[0], slot, col).imin(ival(&args[1], slot, col)),
                ("max", 2) => ival(&args[0], slot, col).imax(ival(&args[1], slot, col)),
                // __list_base / __list_total and anything unknown.
                _ => Interval::TOP,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::columnar::schema::muon_event_schema;
    use crate::index::ColumnStats;
    use crate::queryir;

    fn pred(src: &str) -> CutPredicate {
        let prog = queryir::compile(src, &muon_event_schema()).unwrap();
        extract(&prog).expect("program should yield a predicate")
    }

    /// A stats lookup with fixed per-column intervals, `col 0 = muons.pt`
    /// in the sources below.
    fn with_pt(lo: f64, hi: f64, nan: bool) -> impl Fn(usize) -> Interval {
        move |c| {
            if c == 0 {
                Interval { lo, hi, nan }
            } else {
                Interval::TOP
            }
        }
    }

    const CUT: &str = "\
for event in dataset:
    for muon in event.muons:
        if muon.pt > 25:
            fill(muon.pt)
";

    #[test]
    fn simple_cut_classifies_all_three_ways() {
        let p = pred(CUT);
        assert_eq!(p.classify_with(&with_pt(1.0, 10.0, false)), ZoneDecision::Skip);
        assert_eq!(p.classify_with(&with_pt(30.0, 90.0, false)), ZoneDecision::TakeAll);
        assert_eq!(p.classify_with(&with_pt(10.0, 90.0, false)), ZoneDecision::Scan);
        // The cut boundary itself is not provably passing.
        assert_eq!(p.classify_with(&with_pt(25.0, 90.0, false)), ZoneDecision::Scan);
    }

    #[test]
    fn nan_columns_block_take_all_but_not_skip() {
        let p = pred(CUT);
        // NaN items fail the cut on both analysis and execution sides.
        assert_eq!(p.classify_with(&with_pt(1.0, 10.0, true)), ZoneDecision::Skip);
        assert_eq!(p.classify_with(&with_pt(30.0, 90.0, true)), ZoneDecision::Scan);
    }

    #[test]
    fn else_branch_negation_prevents_skip() {
        let src = "\
for event in dataset:
    for muon in event.muons:
        if muon.pt > 25:
            fill(muon.pt)
        else:
            fill(muon.eta)
";
        let p = pred(src);
        // Some fill fires for every item whatever pt is, so the zone can
        // never Skip — but it can't TakeAll either: dropping *all* masks
        // would fire both branches on every item. One branch provably
        // dead still leaves the other's mask load-bearing: Scan.
        assert_eq!(p.classify_with(&with_pt(1.0, 10.0, false)), ZoneDecision::Scan);
        assert_eq!(p.classify_with(&with_pt(30.0, 90.0, false)), ZoneDecision::Scan);
        assert_eq!(p.classify_with(&with_pt(10.0, 90.0, false)), ZoneDecision::Scan);
    }

    #[test]
    fn nested_cuts_conjoin_and_unconditional_fills_prevent_skip() {
        let src = "\
for event in dataset:
    for muon in event.muons:
        if muon.pt > 25:
            if muon.pt < 50:
                fill(muon.pt)
";
        let p = pred(src);
        assert_eq!(p.classify_with(&with_pt(60.0, 90.0, false)), ZoneDecision::Skip);
        assert_eq!(p.classify_with(&with_pt(30.0, 40.0, false)), ZoneDecision::TakeAll);

        let src2 = "\
for event in dataset:
    for muon in event.muons:
        fill(muon.eta)
        if muon.pt > 25:
            fill(muon.pt)
";
        let p2 = pred(src2);
        assert_eq!(p2.classify_with(&with_pt(1.0, 10.0, false)), ZoneDecision::Scan);
        assert_eq!(p2.classify_with(&with_pt(30.0, 90.0, false)), ZoneDecision::TakeAll);
    }

    #[test]
    fn monotone_builtins_prune() {
        let src = "\
for event in dataset:
    for muon in event.muons:
        if sqrt(muon.pt) > 5:
            fill(muon.pt)
";
        let p = pred(src);
        // sqrt(pt) <= 4.9 < 5 for pt <= 24.
        assert_eq!(p.classify_with(&with_pt(1.0, 24.0, false)), ZoneDecision::Skip);
        assert_eq!(p.classify_with(&with_pt(26.0, 99.0, false)), ZoneDecision::TakeAll);
    }

    #[test]
    fn non_fused_programs_yield_no_predicate() {
        let schema = muon_event_schema();
        let max_pt = queryir::compile(queryir::table3::MAX_PT, &schema).unwrap();
        assert!(extract(&max_pt).is_none());
        let pairs = queryir::compile(queryir::table3::MASS_PAIRS, &schema).unwrap();
        assert!(extract(&pairs).is_none());
        // Unconditional flat fills do yield one (a single None mask): they
        // can be proven TakeAll but never skipped.
        let flat = queryir::compile(queryir::table3::MUON_PT, &schema).unwrap();
        let p = extract(&flat).unwrap();
        assert_eq!(p.classify_with(&|_| Interval::TOP), ZoneDecision::TakeAll);
    }

    #[test]
    fn chunk_classification_uses_per_chunk_stats() {
        use crate::columnar::arrays::{Array, ColumnSet};
        let mut cs = ColumnSet::empty(muon_event_schema());
        cs.n_events = 2;
        cs.offsets.insert("muons".into(), vec![0, 3, 6]);
        cs.leaves.insert(
            "muons.pt".into(),
            Array::F32(vec![1.0, 2.0, 3.0, 40.0, 50.0, 60.0]),
        );
        for path in ["muons.eta", "muons.phi"] {
            cs.leaves.insert(path.into(), Array::F32(vec![0.0; 6]));
        }
        cs.leaves
            .insert("muons.charge".into(), Array::I32(vec![1; 6]));
        cs.leaves.insert("met".into(), Array::F32(vec![0.0; 2]));
        let zm = crate::index::ZoneMap::build_with_chunk(&cs, 3);
        let p = pred(CUT);
        let d = p.classify_chunks(&zm).unwrap();
        assert_eq!(d, vec![ZoneDecision::Skip, ZoneDecision::TakeAll]);
        assert_eq!(p.classify_partition(&zm), ZoneDecision::Scan);
    }

    #[test]
    fn missing_columns_degrade_to_scan() {
        let p = pred(CUT);
        let zm = crate::index::ZoneMap {
            chunk_items: 4,
            columns: Default::default(),
        };
        assert_eq!(p.classify_partition(&zm), ZoneDecision::Scan);
        assert!(p.classify_chunks(&zm).is_none());
    }

    #[test]
    fn interval_eval_covers_boolean_subexpressions() {
        // `(pt > 10) + 1 > 1` is true exactly when the cut passes; the
        // boolean refinement keeps it decidable.
        let src = "\
for event in dataset:
    for muon in event.muons:
        if not muon.pt > 10:
            fill(muon.pt)
";
        let p = pred(src);
        assert_eq!(p.classify_with(&with_pt(20.0, 30.0, false)), ZoneDecision::Skip);
        assert_eq!(p.classify_with(&with_pt(1.0, 5.0, false)), ZoneDecision::TakeAll);
    }

    /// Stats-derived intervals plug straight in.
    #[test]
    fn column_stats_drive_classification() {
        let mut s = ColumnStats::empty();
        for v in [30.0, 40.0, 55.0] {
            s.update(v);
        }
        let p = pred(CUT);
        let d = p.classify_with(&|c| {
            if c == 0 {
                s.interval()
            } else {
                Interval::TOP
            }
        });
        assert_eq!(d, ZoneDecision::TakeAll);
    }
}
