//! Executor for transformed (flat-loop) programs.
//!
//! Runs a `FlatProgram` directly over exploded arrays: the only mutable
//! state is a `Vec<f64>` of slots, there is no allocation inside the event
//! loop, and attribute loads are plain array indexing — the code the paper
//! hands to Numba/Clang, here evaluated by a tight recursive interpreter
//! over a resolved-column view (`engine::columnar_exec` plays the role of
//! the fully compiled endpoint).

use super::ast::{apply_builtin, BinOp, CmpOp};
use super::transform::{CExpr, CStmt, FlatProgram};
use crate::columnar::arrays::ColumnSet;
use crate::hist::{Sink, SinkSet, H1};

/// Column views resolved once per partition.
struct Ctx<'a> {
    item_cols: Vec<&'a [f32]>,
    event_cols: Vec<&'a [f32]>,
    offsets: Vec<&'a [i64]>,
    slots: Vec<f64>,
    /// Current event index.
    event: usize,
}

pub fn run(prog: &FlatProgram, cs: &ColumnSet, hist: &mut H1) -> Result<(), String> {
    require_no_aux(prog)?;
    run_inner(prog, cs, hist, &mut [], true)
}

/// Run without the fusion optimization (for the ablation bench).
pub fn run_unfused(prog: &FlatProgram, cs: &ColumnSet, hist: &mut H1) -> Result<(), String> {
    require_no_aux(prog)?;
    run_inner(prog, cs, hist, &mut [], false)
}

/// Run a program with aux sinks (`fill2`/`profile`/`fill_vars`): the
/// primary `H1` and one pre-built sink per `prog.aux` entry (see
/// `FlatProgram::make_aux`).
pub fn run_group(
    prog: &FlatProgram,
    cs: &ColumnSet,
    hist: &mut H1,
    aux: &mut [Sink],
) -> Result<(), String> {
    run_inner(prog, cs, hist, aux, true)
}

/// An H1-only entry point refuses programs with aux sinks rather than
/// silently dropping their fills.
pub(crate) fn require_no_aux(prog: &FlatProgram) -> Result<(), String> {
    if prog.aux.is_empty() {
        Ok(())
    } else {
        Err(format!(
            "query has {} aux sink(s) (fill2/profile/fill_vars); use the group API",
            prog.aux.len()
        ))
    }
}

fn run_inner(
    prog: &FlatProgram,
    cs: &ColumnSet,
    hist: &mut H1,
    aux: &mut [Sink],
    allow_fused: bool,
) -> Result<(), String> {
    if aux.len() != prog.aux.len() {
        return Err(format!(
            "aux sink count mismatch: program declares {}, caller passed {}",
            prog.aux.len(),
            aux.len()
        ));
    }
    let mut item_cols = Vec::with_capacity(prog.item_cols.len());
    for path in &prog.item_cols {
        item_cols.push(
            cs.leaf(path)
                .ok_or_else(|| format!("no leaf '{path}'"))?
                .as_f32()
                .ok_or_else(|| format!("leaf '{path}' is not f32"))?,
        );
    }
    let mut event_cols = Vec::with_capacity(prog.event_cols.len());
    for path in &prog.event_cols {
        event_cols.push(
            cs.leaf(path)
                .ok_or_else(|| format!("no leaf '{path}'"))?
                .as_f32()
                .ok_or_else(|| format!("leaf '{path}' is not f32"))?,
        );
    }
    let mut offsets = Vec::with_capacity(prog.lists.len());
    for path in &prog.lists {
        offsets.push(
            cs.offsets_of(path)
                .ok_or_else(|| format!("no list '{path}'"))?,
        );
    }
    let mut ctx = Ctx {
        item_cols,
        event_cols,
        offsets,
        slots: vec![0.0; prog.n_slots],
        event: 0,
    };
    let mut sinks = SinkSet { primary: hist, aux };
    if let (true, Some(fused)) = (allow_fused, prog.fused.as_ref()) {
        // Single fused loop: `for k in 0..total` — no event iteration.
        ctx.event = 0;
        for s in fused {
            exec(s, &mut ctx, &mut sinks)?;
        }
        return Ok(());
    }
    for ev in 0..cs.n_events {
        ctx.event = ev;
        for s in &prog.body {
            exec(s, &mut ctx, &mut sinks)?;
        }
    }
    Ok(())
}

fn exec(s: &CStmt, ctx: &mut Ctx, sinks: &mut SinkSet) -> Result<(), String> {
    match s {
        CStmt::Assign { slot, expr } => {
            ctx.slots[*slot] = eval(expr, ctx)?;
            Ok(())
        }
        CStmt::LoopRange { slot, lo, hi, body } => {
            let lo = eval(lo, ctx)? as i64;
            let hi = eval(hi, ctx)? as i64;
            for k in lo..hi {
                ctx.slots[*slot] = k as f64;
                for s in body {
                    exec(s, ctx, sinks)?;
                }
            }
            Ok(())
        }
        CStmt::LoopList { list, slot, body } => {
            let off = ctx.offsets[*list];
            let (lo, hi) = (off[ctx.event] as i64, off[ctx.event + 1] as i64);
            for k in lo..hi {
                ctx.slots[*slot] = k as f64;
                for s in body {
                    exec(s, ctx, sinks)?;
                }
            }
            Ok(())
        }
        CStmt::If { cond, then, els } => {
            let branch = if eval(cond, ctx)? != 0.0 { then } else { els };
            for s in branch {
                exec(s, ctx, sinks)?;
            }
            Ok(())
        }
        CStmt::Fill { expr, weight } => {
            let x = eval(expr, ctx)?;
            let w = match weight {
                Some(w) => eval(w, ctx)?,
                None => 1.0,
            };
            sinks.primary.fill_w(x, w);
            Ok(())
        }
        CStmt::Fill2 { sink, x, y, weight } => {
            let xv = eval(x, ctx)?;
            let yv = eval(y, ctx)?;
            let w = match weight {
                Some(w) => eval(w, ctx)?,
                None => 1.0,
            };
            sinks.fill2(*sink, xv, yv, w)
        }
        CStmt::FillProf { sink, x, y, weight } => {
            let xv = eval(x, ctx)?;
            let yv = eval(y, ctx)?;
            let w = match weight {
                Some(w) => eval(w, ctx)?,
                None => 1.0,
            };
            sinks.fill_prof(*sink, xv, yv, w)
        }
        CStmt::FillVars { sink, x, weights } => {
            let xv = eval(x, ctx)?;
            for (k, w) in weights.iter().enumerate() {
                let wv = eval(w, ctx)?;
                sinks.fill_var(*sink + k, xv, wv)?;
            }
            Ok(())
        }
    }
}

fn eval(e: &CExpr, ctx: &Ctx) -> Result<f64, String> {
    Ok(match e {
        CExpr::Const(n) => *n,
        CExpr::Slot(s) => ctx.slots[*s],
        CExpr::LoadItem { col, idx } => {
            let k = eval(idx, ctx)? as usize;
            let arr = ctx.item_cols[*col];
            *arr.get(k)
                .ok_or_else(|| format!("index {k} out of bounds (len {})", arr.len()))?
                as f64
        }
        CExpr::LoadEvent { col } => {
            let arr = ctx.event_cols[*col];
            *arr.get(ctx.event)
                .ok_or_else(|| format!("event {} out of bounds", ctx.event))? as f64
        }
        CExpr::ListLen { list } => {
            let off = ctx.offsets[*list];
            (off[ctx.event + 1] - off[ctx.event]) as f64
        }
        CExpr::Bin(op, l, r) => {
            let (a, b) = (eval(l, ctx)?, eval(r, ctx)?);
            match op {
                BinOp::Add => a + b,
                BinOp::Sub => a - b,
                BinOp::Mul => a * b,
                BinOp::Div => a / b,
            }
        }
        CExpr::Cmp(op, l, r) => {
            let (a, b) = (eval(l, ctx)?, eval(r, ctx)?);
            let t = match op {
                CmpOp::Lt => a < b,
                CmpOp::Le => a <= b,
                CmpOp::Gt => a > b,
                CmpOp::Ge => a >= b,
                CmpOp::Eq => a == b,
                CmpOp::Ne => a != b,
            };
            t as i64 as f64
        }
        CExpr::And(l, r) => {
            if eval(l, ctx)? != 0.0 {
                (eval(r, ctx)? != 0.0) as i64 as f64
            } else {
                0.0
            }
        }
        CExpr::Or(l, r) => {
            if eval(l, ctx)? != 0.0 {
                1.0
            } else {
                (eval(r, ctx)? != 0.0) as i64 as f64
            }
        }
        CExpr::Not(x) => (eval(x, ctx)? == 0.0) as i64 as f64,
        CExpr::Neg(x) => -eval(x, ctx)?,
        CExpr::Call(name, args) => match *name {
            // `list[j]` → offsets[list][i] + j.
            "__list_base" => {
                let lid = eval(&args[0], ctx)? as usize;
                let j = eval(&args[1], ctx)?;
                ctx.offsets[lid][ctx.event] as f64 + j
            }
            // Fusion bound: total content length of a list.
            "__list_total" => {
                let lid = eval(&args[0], ctx)? as usize;
                *ctx.offsets[lid].last().unwrap() as f64
            }
            _ => {
                let vals = args
                    .iter()
                    .map(|a| eval(a, ctx))
                    .collect::<Result<Vec<_>, _>>()?;
                apply_builtin(name, &vals)?
            }
        },
    })
}
