//! Object-view interpreter — the *untransformed* baseline.
//!
//! Materializes each event as a generic object tree (the GetEntry path) and
//! walks the AST directly, exactly as a physicist's Python would run before
//! any transformation/compilation. Figure 1's gap between this and the flat
//! executor is the paper's code-transformation payoff.

use super::ast::{apply_builtin, BinOp, CmpOp, Expr, Iter, Program, Stmt};
use crate::columnar::arrays::ColumnSet;
use crate::columnar::explode::{materialize, Value};
use crate::hist::{Sink, SinkSet, H1};
use std::collections::HashMap;
use std::rc::Rc;

#[derive(Clone, Debug)]
enum RtVal {
    Num(f64),
    Node(Rc<Value>),
}

/// Statement-pointer → base aux-sink index, assigned in source order
/// (`then` before `els`) so the numbering matches `Transformer`'s.
struct AuxMap {
    sinks: Vec<(*const Stmt, usize)>,
    n_sinks: usize,
}

impl AuxMap {
    fn build(prog: &Program) -> AuxMap {
        let mut m = AuxMap { sinks: Vec::new(), n_sinks: 0 };
        m.scan(&prog.body);
        m
    }

    fn scan(&mut self, body: &[Stmt]) {
        for s in body {
            match s {
                Stmt::Fill2(..) | Stmt::FillProf(..) => {
                    self.sinks.push((s as *const Stmt, self.n_sinks));
                    self.n_sinks += 1;
                }
                Stmt::FillVars(_, ws) => {
                    self.sinks.push((s as *const Stmt, self.n_sinks));
                    self.n_sinks += ws.len();
                }
                Stmt::For { body, .. } => self.scan(body),
                Stmt::If { then, els, .. } => {
                    self.scan(then);
                    self.scan(els);
                }
                Stmt::Assign(..) | Stmt::Fill(..) => {}
            }
        }
    }

    fn sink_of(&self, s: &Stmt) -> usize {
        let p = s as *const Stmt;
        self.sinks
            .iter()
            .find(|(q, _)| *q == p)
            .map(|(_, i)| *i)
            .expect("aux statement not indexed")
    }
}

pub fn run(prog: &Program, cs: &ColumnSet, hist: &mut H1) -> Result<(), String> {
    run_group(prog, cs, hist, &mut [])
}

/// Run with aux sinks (`fill2`/`profile`/`fill_vars`): caller passes one
/// pre-built sink per aux declaration (`FlatProgram::make_aux` shapes).
pub fn run_group(
    prog: &Program,
    cs: &ColumnSet,
    hist: &mut H1,
    aux: &mut [Sink],
) -> Result<(), String> {
    let map = AuxMap::build(prog);
    if map.n_sinks != aux.len() {
        return Err(format!(
            "query has {} aux sink(s), caller passed {} (use the group API)",
            map.n_sinks,
            aux.len()
        ));
    }
    let mut sinks = SinkSet { primary: hist, aux };
    let mut env: HashMap<String, RtVal> = HashMap::new();
    for i in 0..cs.n_events {
        let event = Rc::new(materialize(cs, i)?);
        env.insert(prog.event_var.clone(), RtVal::Node(event));
        for s in &prog.body {
            exec(s, &mut env, &mut sinks, &map)?;
        }
    }
    Ok(())
}

/// Run over pre-materialized events (to time the analysis loop separately
/// from materialization).
pub fn run_materialized(prog: &Program, events: &[Value], hist: &mut H1) -> Result<(), String> {
    let map = AuxMap::build(prog);
    if map.n_sinks != 0 {
        return Err("program has aux sinks; use run_group".into());
    }
    let mut sinks = SinkSet { primary: hist, aux: &mut [] };
    let mut env: HashMap<String, RtVal> = HashMap::new();
    for ev in events {
        env.insert(prog.event_var.clone(), RtVal::Node(Rc::new(ev.clone())));
        for s in &prog.body {
            exec(s, &mut env, &mut sinks, &map)?;
        }
    }
    Ok(())
}

fn exec(
    s: &Stmt,
    env: &mut HashMap<String, RtVal>,
    sinks: &mut SinkSet,
    map: &AuxMap,
) -> Result<(), String> {
    match s {
        Stmt::Assign(name, e) => {
            let v = eval(e, env)?;
            env.insert(name.clone(), v);
            Ok(())
        }
        Stmt::For { var, iter, body } => {
            match iter {
                Iter::Dataset => return Err("nested dataset loop".into()),
                Iter::Range(lo, hi) => {
                    let lo = match lo {
                        Some(e) => as_num(&eval(e, env)?)? as i64,
                        None => 0,
                    };
                    let hi = as_num(&eval(hi, env)?)? as i64;
                    for k in lo..hi {
                        env.insert(var.clone(), RtVal::Num(k as f64));
                        for s in body {
                            exec(s, env, sinks, map)?;
                        }
                    }
                }
                Iter::List(e) => {
                    let node = as_node(&eval(e, env)?)?;
                    let items = node
                        .as_list()
                        .ok_or("loop target is not a list")?
                        .to_vec();
                    for item in items {
                        env.insert(var.clone(), RtVal::Node(Rc::new(item)));
                        for s in body {
                            exec(s, env, sinks, map)?;
                        }
                    }
                }
            }
            Ok(())
        }
        Stmt::If { cond, then, els } => {
            let c = as_num(&eval(cond, env)?)?;
            let branch = if c != 0.0 { then } else { els };
            for s in branch {
                exec(s, env, sinks, map)?;
            }
            Ok(())
        }
        Stmt::Fill(e, w) => {
            let x = as_num(&eval(e, env)?)?;
            let w = match w {
                Some(w) => as_num(&eval(w, env)?)?,
                None => 1.0,
            };
            sinks.primary.fill_w(x, w);
            Ok(())
        }
        Stmt::Fill2(x, y, w) => {
            let xv = as_num(&eval(x, env)?)?;
            let yv = as_num(&eval(y, env)?)?;
            let wv = match w {
                Some(w) => as_num(&eval(w, env)?)?,
                None => 1.0,
            };
            sinks.fill2(map.sink_of(s), xv, yv, wv)
        }
        Stmt::FillProf(x, y, w) => {
            let xv = as_num(&eval(x, env)?)?;
            let yv = as_num(&eval(y, env)?)?;
            let wv = match w {
                Some(w) => as_num(&eval(w, env)?)?,
                None => 1.0,
            };
            sinks.fill_prof(map.sink_of(s), xv, yv, wv)
        }
        Stmt::FillVars(x, ws) => {
            let xv = as_num(&eval(x, env)?)?;
            let base = map.sink_of(s);
            for (k, w) in ws.iter().enumerate() {
                let wv = as_num(&eval(w, env)?)?;
                sinks.fill_var(base + k, xv, wv)?;
            }
            Ok(())
        }
    }
}

fn as_num(v: &RtVal) -> Result<f64, String> {
    match v {
        RtVal::Num(n) => Ok(*n),
        RtVal::Node(n) => n.as_f64().ok_or_else(|| "expected a number".to_string()),
    }
}

fn as_node(v: &RtVal) -> Result<Rc<Value>, String> {
    match v {
        RtVal::Node(n) => Ok(n.clone()),
        RtVal::Num(_) => Err("expected an object".into()),
    }
}

fn eval(e: &Expr, env: &HashMap<String, RtVal>) -> Result<RtVal, String> {
    Ok(match e {
        Expr::Num(n) => RtVal::Num(*n),
        Expr::Var(name) => env
            .get(name)
            .cloned()
            .ok_or_else(|| format!("unknown variable '{name}'"))?,
        Expr::Attr(base, attr) => {
            let node = as_node(&eval(base, env)?)?;
            let v = node
                .get(attr)
                .ok_or_else(|| format!("no attribute '{attr}'"))?
                .clone();
            RtVal::Node(Rc::new(v))
        }
        Expr::Index(base, idx) => {
            let node = as_node(&eval(base, env)?)?;
            let items = node.as_list().ok_or("indexing a non-list")?;
            let k = as_num(&eval(idx, env)?)? as usize;
            RtVal::Node(Rc::new(
                items
                    .get(k)
                    .ok_or_else(|| format!("index {k} out of range"))?
                    .clone(),
            ))
        }
        Expr::Bin(op, l, r) => {
            let (a, b) = (as_num(&eval(l, env)?)?, as_num(&eval(r, env)?)?);
            RtVal::Num(match op {
                BinOp::Add => a + b,
                BinOp::Sub => a - b,
                BinOp::Mul => a * b,
                BinOp::Div => a / b,
            })
        }
        Expr::Cmp(op, l, r) => {
            let (a, b) = (as_num(&eval(l, env)?)?, as_num(&eval(r, env)?)?);
            let t = match op {
                CmpOp::Lt => a < b,
                CmpOp::Le => a <= b,
                CmpOp::Gt => a > b,
                CmpOp::Ge => a >= b,
                CmpOp::Eq => a == b,
                CmpOp::Ne => a != b,
            };
            RtVal::Num(t as i64 as f64)
        }
        Expr::And(l, r) => {
            if as_num(&eval(l, env)?)? != 0.0 {
                RtVal::Num((as_num(&eval(r, env)?)? != 0.0) as i64 as f64)
            } else {
                RtVal::Num(0.0)
            }
        }
        Expr::Or(l, r) => {
            if as_num(&eval(l, env)?)? != 0.0 {
                RtVal::Num(1.0)
            } else {
                RtVal::Num((as_num(&eval(r, env)?)? != 0.0) as i64 as f64)
            }
        }
        Expr::Not(x) => RtVal::Num((as_num(&eval(x, env)?)? == 0.0) as i64 as f64),
        Expr::Neg(x) => RtVal::Num(-as_num(&eval(x, env)?)?),
        Expr::Call(name, args) => {
            if name == "len" {
                let node = as_node(&eval(&args[0], env)?)?;
                let items = node.as_list().ok_or("len of a non-list")?;
                return Ok(RtVal::Num(items.len() as f64));
            }
            let vals = args
                .iter()
                .map(|a| eval(a, env).and_then(|v| as_num(&v)))
                .collect::<Result<Vec<_>, _>>()?;
            RtVal::Num(apply_builtin(name, &vals)?)
        }
    })
}
