//! Observability: the unified metrics registry ([`metrics`]) and
//! per-query trace spans ([`trace`]).
//!
//! Everything the server exports through `{"op":"metrics"}` and
//! `{"op":"trace"}` is defined here; `docs/OBSERVABILITY.md` is the
//! operator-facing catalog (metric names, span taxonomy, EXPLAIN
//! walkthrough, Prometheus scrape config).

pub mod metrics;
pub mod trace;

pub use metrics::{Counter, Gauge, Histo, HistoSnap, Registry, Snapshot};
pub use trace::{Span, TraceBuf, TraceMap, Tracer};
