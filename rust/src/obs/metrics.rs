//! Unified metrics registry: lock-free counters, gauges, and log-scale
//! histograms registered by name, exported as JSON or Prometheus text
//! exposition format.
//!
//! Handles are cheap clones of `Arc`-wrapped atomics: a counter bump on
//! the hot path is a single `fetch_add(Relaxed)`, and the registry's
//! name maps are only locked at registration and export time. The
//! registry is deliberately **instance-scoped** — each `Server` owns
//! one — rather than process-global: the test suite runs many servers
//! in one process, and a shared registry would cross-contaminate their
//! exact-count assertions (`queries_executed == 1` and the like).
//!
//! Histograms are log-scale (power-of-two buckets): `observe(v)` lands
//! `v` in the bucket holding its bit length, so quantiles come back as
//! the upper edge of the containing bucket — within 2x of the true
//! value across the full `u64` range, at the cost of 65 fixed counters
//! and zero allocation. The scale (µs, bytes, …) is the caller's
//! convention and belongs in the metric name (`query_exec_us`).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::util::json::Json;

/// Monotonic event count. Clones share the underlying atomic.
#[derive(Clone, Default)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Point-in-time signed value (queue depths, live connections).
#[derive(Clone, Default)]
pub struct Gauge(Arc<AtomicI64>);

impl Gauge {
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn add(&self, d: i64) {
        self.0.fetch_add(d, Ordering::Relaxed);
    }

    pub fn sub(&self, d: i64) {
        self.0.fetch_sub(d, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Bucket `i` holds values with bit length `i`: bucket 0 is exactly 0,
/// bucket `i >= 1` covers `[2^(i-1), 2^i)`. 65 buckets span all of `u64`.
const HISTO_BUCKETS: usize = 65;

struct HistoInner {
    buckets: [AtomicU64; HISTO_BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

/// Log-scale histogram for latencies and sizes. Clones share state.
#[derive(Clone)]
pub struct Histo(Arc<HistoInner>);

impl Histo {
    fn new() -> Histo {
        Histo(Arc::new(HistoInner {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }))
    }

    /// Record one observation. Four relaxed atomic ops, no locks.
    pub fn observe(&self, v: u64) {
        let idx = (u64::BITS - v.leading_zeros()) as usize;
        self.0.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.0.count.fetch_add(1, Ordering::Relaxed);
        self.0.sum.fetch_add(v, Ordering::Relaxed);
        self.0.max.fetch_max(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.0.count.load(Ordering::Relaxed)
    }

    pub fn snap(&self) -> HistoSnap {
        let counts: Vec<u64> = self
            .0
            .buckets
            .iter()
            .map(|b| b.load(Ordering::Relaxed))
            .collect();
        let count = self.0.count.load(Ordering::Relaxed);
        HistoSnap {
            count,
            sum: self.0.sum.load(Ordering::Relaxed),
            max: self.0.max.load(Ordering::Relaxed),
            p50: quantile(&counts, count, 0.50),
            p90: quantile(&counts, count, 0.90),
            p99: quantile(&counts, count, 0.99),
        }
    }
}

/// Upper edge of the bucket where the cumulative count first reaches
/// `q * total` — a conservative (never-underestimating) quantile.
fn quantile(counts: &[u64], total: u64, q: f64) -> u64 {
    if total == 0 {
        return 0;
    }
    let target = ((total as f64 * q).ceil() as u64).max(1);
    let mut cum = 0u64;
    for (i, &c) in counts.iter().enumerate() {
        cum += c;
        if cum >= target {
            return bucket_upper_edge(i);
        }
    }
    bucket_upper_edge(HISTO_BUCKETS - 1)
}

fn bucket_upper_edge(i: usize) -> u64 {
    if i == 0 {
        0
    } else if i >= 64 {
        u64::MAX
    } else {
        (1u64 << i) - 1
    }
}

/// Exported view of one histogram at snapshot time.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct HistoSnap {
    pub count: u64,
    pub sum: u64,
    pub max: u64,
    pub p50: u64,
    pub p90: u64,
    pub p99: u64,
}

/// Named metric handles, get-or-create by name. See the module docs for
/// why this is instance-scoped rather than a process-global.
#[derive(Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, Counter>>,
    gauges: Mutex<BTreeMap<String, Gauge>>,
    histos: Mutex<BTreeMap<String, Histo>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Get or create the counter registered under `name`.
    pub fn counter(&self, name: &str) -> Counter {
        let mut m = self.counters.lock().unwrap();
        m.entry(name.to_string()).or_default().clone()
    }

    /// Get or create the gauge registered under `name`.
    pub fn gauge(&self, name: &str) -> Gauge {
        let mut m = self.gauges.lock().unwrap();
        m.entry(name.to_string()).or_default().clone()
    }

    /// Get or create the histogram registered under `name`.
    pub fn histo(&self, name: &str) -> Histo {
        let mut m = self.histos.lock().unwrap();
        m.entry(name.to_string()).or_insert_with(Histo::new).clone()
    }

    /// Freeze every registered metric into an exportable snapshot.
    /// Subsystems that keep their own counters (placement stats, cache
    /// stats, queue depths) are merged in afterwards via
    /// [`Snapshot::set_counter`] / [`Snapshot::set_gauge`].
    pub fn snapshot(&self) -> Snapshot {
        let mut snap = Snapshot::default();
        for (name, c) in self.counters.lock().unwrap().iter() {
            snap.counters.insert(name.clone(), c.get());
        }
        for (name, g) in self.gauges.lock().unwrap().iter() {
            snap.gauges.insert(name.clone(), g.get());
        }
        for (name, h) in self.histos.lock().unwrap().iter() {
            snap.histos.insert(name.clone(), h.snap());
        }
        snap
    }
}

/// Point-in-time view of every metric, renderable as JSON or Prometheus
/// text exposition format.
#[derive(Default)]
pub struct Snapshot {
    pub counters: BTreeMap<String, u64>,
    pub gauges: BTreeMap<String, i64>,
    pub histos: BTreeMap<String, HistoSnap>,
}

impl Snapshot {
    /// Merge a counter collected from outside the registry (subsystems
    /// that already keep their own atomics export through here).
    pub fn set_counter(&mut self, name: &str, v: u64) {
        self.counters.insert(name.to_string(), v);
    }

    /// Merge an externally collected gauge.
    pub fn set_gauge(&mut self, name: &str, v: i64) {
        self.gauges.insert(name.to_string(), v);
    }

    pub fn to_json(&self) -> Json {
        let counters = Json::Obj(
            self.counters
                .iter()
                .map(|(k, v)| (k.clone(), Json::num(*v as f64)))
                .collect(),
        );
        let gauges = Json::Obj(
            self.gauges
                .iter()
                .map(|(k, v)| (k.clone(), Json::num(*v as f64)))
                .collect(),
        );
        let histos = Json::Obj(
            self.histos
                .iter()
                .map(|(k, h)| {
                    (
                        k.clone(),
                        Json::obj(vec![
                            ("count", Json::num(h.count as f64)),
                            ("sum", Json::num(h.sum as f64)),
                            ("max", Json::num(h.max as f64)),
                            ("p50", Json::num(h.p50 as f64)),
                            ("p90", Json::num(h.p90 as f64)),
                            ("p99", Json::num(h.p99 as f64)),
                        ]),
                    )
                })
                .collect(),
        );
        Json::obj(vec![
            ("counters", counters),
            ("gauges", gauges),
            ("histograms", histos),
        ])
    }

    /// Prometheus text exposition format (v0.0.4): counters and gauges
    /// as single samples, histograms as quantile-labeled summaries.
    /// Names get the `hepq_` prefix and `[a-zA-Z0-9_]` sanitization.
    pub fn to_prometheus(&self) -> String {
        let mut out = String::new();
        for (name, v) in &self.counters {
            let n = prom_name(name);
            out.push_str(&format!("# TYPE {n} counter\n{n} {v}\n"));
        }
        for (name, v) in &self.gauges {
            let n = prom_name(name);
            out.push_str(&format!("# TYPE {n} gauge\n{n} {v}\n"));
        }
        for (name, h) in &self.histos {
            let n = prom_name(name);
            out.push_str(&format!("# TYPE {n} summary\n"));
            out.push_str(&format!("{n}{{quantile=\"0.5\"}} {}\n", h.p50));
            out.push_str(&format!("{n}{{quantile=\"0.9\"}} {}\n", h.p90));
            out.push_str(&format!("{n}{{quantile=\"0.99\"}} {}\n", h.p99));
            out.push_str(&format!("{n}_count {}\n", h.count));
            out.push_str(&format!("{n}_sum {}\n", h.sum));
            out.push_str(&format!("{n}_max {}\n", h.max));
        }
        out
    }
}

fn prom_name(name: &str) -> String {
    let mut n = String::with_capacity(name.len() + 5);
    n.push_str("hepq_");
    for c in name.chars() {
        if c.is_ascii_alphanumeric() || c == '_' {
            n.push(c);
        } else {
            n.push('_');
        }
    }
    n
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_share_state_across_clones() {
        let reg = Registry::new();
        let a = reg.counter("hits");
        let b = reg.counter("hits");
        a.inc();
        b.add(2);
        assert_eq!(reg.counter("hits").get(), 3);

        let g = reg.gauge("depth");
        g.set(5);
        g.sub(2);
        assert_eq!(reg.gauge("depth").get(), 3);
        g.add(4);
        assert_eq!(g.get(), 7);
    }

    #[test]
    fn histo_quantiles_are_log_bucket_upper_edges() {
        let reg = Registry::new();
        let h = reg.histo("lat_us");
        // 90 observations in [64, 128) and 10 in [1024, 2048).
        for _ in 0..90 {
            h.observe(100);
        }
        for _ in 0..10 {
            h.observe(1500);
        }
        let s = h.snap();
        assert_eq!(s.count, 100);
        assert_eq!(s.sum, 90 * 100 + 10 * 1500);
        assert_eq!(s.max, 1500);
        assert_eq!(s.p50, 127); // upper edge of [64, 128)
        assert_eq!(s.p90, 127);
        assert_eq!(s.p99, 2047); // upper edge of [1024, 2048)
    }

    #[test]
    fn histo_handles_zero_and_empty() {
        let reg = Registry::new();
        let h = reg.histo("x");
        assert_eq!(h.snap(), HistoSnap::default());
        h.observe(0);
        let s = h.snap();
        assert_eq!((s.count, s.max, s.p50, s.p99), (1, 0, 0, 0));
    }

    #[test]
    fn snapshot_renders_json_and_prometheus() {
        let reg = Registry::new();
        reg.counter("queries_executed").add(7);
        reg.gauge("active_conns").set(2);
        reg.histo("query_exec_us").observe(900);
        let mut snap = reg.snapshot();
        snap.set_counter("cache.hits", 3);

        let j = snap.to_json();
        assert_eq!(j.path("counters.queries_executed").unwrap().as_u64(), Some(7));
        // A dotted metric name is one literal key, not a path.
        let counters = j.get("counters").unwrap();
        assert_eq!(counters.get("cache.hits").unwrap().as_u64(), Some(3));
        assert_eq!(j.path("gauges.active_conns").unwrap().as_i64(), Some(2));
        assert_eq!(
            j.path("histograms.query_exec_us.count").unwrap().as_u64(),
            Some(1)
        );

        let prom = snap.to_prometheus();
        assert!(prom.contains("# TYPE hepq_queries_executed counter"));
        assert!(prom.contains("hepq_queries_executed 7"));
        assert!(prom.contains("# TYPE hepq_cache_hits counter"));
        assert!(prom.contains("# TYPE hepq_active_conns gauge"));
        assert!(prom.contains("# TYPE hepq_query_exec_us summary"));
        assert!(prom.contains("hepq_query_exec_us{quantile=\"0.99\"} 1023"));
        assert!(prom.contains("hepq_query_exec_us_count 1"));
        // Every line is either a comment or `name[{labels}] value`.
        for line in prom.lines() {
            assert!(
                line.starts_with("# TYPE hepq_") || line.starts_with("hepq_"),
                "bad exposition line: {line}"
            );
        }
    }
}
