//! Per-query trace spans: who spent this query's milliseconds, and where.
//!
//! A [`Span`] is a lightweight handle into a per-query [`TraceBuf`].
//! Parentage is **explicit** — `span.child("decode")` — never inferred
//! from thread-locals, because the interesting spans cross threads: a
//! morsel worker or a cluster worker must attach its work to the
//! *submitting query's* trace, not to whatever its own thread last
//! touched. Handles clone freely across threads; ending a span records
//! one [`SpanRec`] into the buffer's bounded vector (excess spans are
//! counted as dropped, never reallocating without bound).
//!
//! Overhead discipline: when tracing is off every span is
//! [`Span::none`] — a `None` buffer — so `child`/`event`/`end` are a
//! branch on an `Option`, and the cluster fast path guards on a single
//! relaxed atomic load ([`TraceMap::any`]) before even looking a span
//! up. A bench rung (`bench_table1` `cluster_trace_off`) holds this to
//! within noise of the untraced baseline.
//!
//! Finished traces render three ways: a span tree with per-node
//! `self_us` ([`span_tree_json`], the `{"op":"trace"}` response), Chrome
//! `trace_event` JSON ([`chrome_trace_json`], loadable in
//! `chrome://tracing` / Perfetto), and a condensed indented text form
//! ([`condensed`]) for the slow-query log.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Instant;

use crate::util::json::Json;

/// Spans kept per query before further `end()`s count as dropped.
const MAX_SPANS: usize = 8192;

/// Finished traces kept per server before the oldest is evicted.
const RING_CAP: usize = 64;

static NEXT_TID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    /// Small dense per-thread id for trace rendering (a `u64` rank in
    /// first-use order, stable for the thread's lifetime). This
    /// thread-local is *identity*, not parentage — parent spans are
    /// always passed explicitly.
    static TID: u64 = NEXT_TID.fetch_add(1, Ordering::Relaxed);
}

fn current_tid() -> u64 {
    TID.with(|t| *t)
}

/// One finished span interval, as stored in a [`TraceBuf`].
#[derive(Clone, Debug)]
pub struct SpanRec {
    pub id: u64,
    pub parent: u64,
    pub name: &'static str,
    pub meta: Option<String>,
    pub start_us: u64,
    pub end_us: u64,
    pub tid: u64,
}

/// Bounded per-query span buffer. Timestamps are µs since the buffer's
/// creation (`epoch`), so every span in one trace shares a clock.
pub struct TraceBuf {
    pub trace_id: u64,
    epoch: Instant,
    next_span: AtomicU64,
    spans: Mutex<Vec<SpanRec>>,
    dropped: AtomicU64,
}

impl TraceBuf {
    fn new(trace_id: u64) -> TraceBuf {
        TraceBuf {
            trace_id,
            epoch: Instant::now(),
            next_span: AtomicU64::new(1),
            spans: Mutex::new(Vec::new()),
            dropped: AtomicU64::new(0),
        }
    }

    fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    fn alloc_id(&self) -> u64 {
        self.next_span.fetch_add(1, Ordering::Relaxed)
    }

    fn push(&self, rec: SpanRec) {
        let mut v = self.spans.lock().unwrap();
        if v.len() >= MAX_SPANS {
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        v.push(rec);
    }

    /// Copy of the recorded spans (finished spans only).
    pub fn recs(&self) -> Vec<SpanRec> {
        self.spans.lock().unwrap().clone()
    }

    pub fn len(&self) -> usize {
        self.spans.lock().unwrap().len()
    }

    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }
}

/// A live span. Clone it to hand the same parent to several threads;
/// call [`Span::end`] exactly once per span you want recorded. Dropping
/// without `end()` records nothing (deliberate: cancelled work leaves
/// its parent interval to tell the story).
#[derive(Clone)]
pub struct Span {
    buf: Option<Arc<TraceBuf>>,
    id: u64,
    parent: u64,
    name: &'static str,
    meta: Option<String>,
    start_us: u64,
}

impl Span {
    /// The no-op span: every operation on it is a branch and a return.
    pub fn none() -> Span {
        Span {
            buf: None,
            id: 0,
            parent: 0,
            name: "",
            meta: None,
            start_us: 0,
        }
    }

    fn root(buf: Arc<TraceBuf>, name: &'static str, meta: Option<String>) -> Span {
        let id = buf.alloc_id();
        let start_us = buf.now_us();
        Span {
            buf: Some(buf),
            id,
            parent: 0,
            name,
            meta,
            start_us,
        }
    }

    /// Is this span actually recording?
    pub fn is_on(&self) -> bool {
        self.buf.is_some()
    }

    /// Trace this span belongs to, 0 for [`Span::none`].
    pub fn trace_id(&self) -> u64 {
        self.buf.as_ref().map_or(0, |b| b.trace_id)
    }

    /// Open a child span starting now.
    pub fn child(&self, name: &'static str) -> Span {
        self.child_inner(name, None)
    }

    /// Open a child span carrying a metadata string (dataset, partition
    /// id, …). The meta allocation only happens on traced queries —
    /// callers on hot paths should guard with [`Span::is_on`].
    pub fn child_meta(&self, name: &'static str, meta: String) -> Span {
        self.child_inner(name, Some(meta))
    }

    fn child_inner(&self, name: &'static str, meta: Option<String>) -> Span {
        match &self.buf {
            None => Span::none(),
            Some(buf) => Span {
                buf: Some(Arc::clone(buf)),
                id: buf.alloc_id(),
                parent: self.id,
                name,
                meta,
                start_us: buf.now_us(),
            },
        }
    }

    /// Record an instantaneous event under this span (failover,
    /// speculation, reap — things with a moment but no duration).
    pub fn event(&self, name: &'static str, meta: Option<String>) {
        if let Some(buf) = &self.buf {
            let now = buf.now_us();
            buf.push(SpanRec {
                id: buf.alloc_id(),
                parent: self.id,
                name,
                meta,
                start_us: now,
                end_us: now,
                tid: current_tid(),
            });
        }
    }

    /// Close the span, recording its interval.
    pub fn end(self) {
        if let Some(buf) = &self.buf {
            buf.push(SpanRec {
                id: self.id,
                parent: self.parent,
                name: self.name,
                meta: self.meta.clone(),
                start_us: self.start_us,
                end_us: buf.now_us(),
                tid: current_tid(),
            });
        }
    }

    /// Close the span, attaching (or replacing) its metadata — for
    /// facts only known at completion (event counts, cache verdicts).
    pub fn end_meta(mut self, meta: String) {
        if self.buf.is_some() {
            self.meta = Some(meta);
        }
        self.end();
    }
}

/// Per-server trace collector: decides whether new queries trace, and
/// keeps the last [`RING_CAP`] trace buffers for `{"op":"trace"}`.
pub struct Tracer {
    enabled: AtomicBool,
    next_trace: AtomicU64,
    ring: Mutex<VecDeque<Arc<TraceBuf>>>,
}

impl Tracer {
    pub fn new(enabled: bool) -> Tracer {
        Tracer {
            enabled: AtomicBool::new(enabled),
            next_trace: AtomicU64::new(1),
            ring: Mutex::new(VecDeque::new()),
        }
    }

    /// One relaxed load — the whole cost of tracing when it is off.
    #[inline]
    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    /// Begin a trace and return its root span. Returns [`Span::none`]
    /// unless tracing is enabled or `force` is set (per-request
    /// `"trace":true`).
    pub fn start(&self, name: &'static str, meta: Option<String>, force: bool) -> Span {
        if !force && !self.enabled() {
            return Span::none();
        }
        let buf = Arc::new(TraceBuf::new(self.next_trace.fetch_add(1, Ordering::Relaxed)));
        let mut ring = self.ring.lock().unwrap();
        ring.push_back(Arc::clone(&buf));
        while ring.len() > RING_CAP {
            ring.pop_front();
        }
        drop(ring);
        Span::root(buf, name, meta)
    }

    /// Fetch a trace by id, or the most recent one when `id` is `None`.
    pub fn get(&self, id: Option<u64>) -> Option<Arc<TraceBuf>> {
        let ring = self.ring.lock().unwrap();
        match id {
            Some(id) => ring.iter().find(|b| b.trace_id == id).cloned(),
            None => ring.back().cloned(),
        }
    }
}

/// Query-id → parent-span table shared between a cluster and its
/// workers, so subtask spans attach to the submitting query's trace.
/// The worker fast path calls [`TraceMap::any`] — one relaxed atomic
/// load — and only takes the lock when at least one live query traces.
#[derive(Default)]
pub struct TraceMap {
    active: AtomicU64,
    map: RwLock<HashMap<u64, Span>>,
}

impl TraceMap {
    pub fn new() -> TraceMap {
        TraceMap::default()
    }

    /// Is any live query tracing? One relaxed atomic load.
    #[inline]
    pub fn any(&self) -> bool {
        self.active.load(Ordering::Relaxed) != 0
    }

    /// Register `qid`'s parent span. No-op for [`Span::none`].
    pub fn insert(&self, qid: u64, span: Span) {
        if !span.is_on() {
            return;
        }
        self.active.fetch_add(1, Ordering::Relaxed);
        if self.map.write().unwrap().insert(qid, span).is_some() {
            // Query ids are unique; tolerate a re-insert anyway.
            self.active.fetch_sub(1, Ordering::Relaxed);
        }
    }

    /// The span registered for `qid`, or [`Span::none`].
    pub fn get(&self, qid: u64) -> Span {
        if !self.any() {
            return Span::none();
        }
        self.map
            .read()
            .unwrap()
            .get(&qid)
            .cloned()
            .unwrap_or_else(Span::none)
    }

    pub fn remove(&self, qid: u64) {
        if !self.any() {
            return;
        }
        if self.map.write().unwrap().remove(&qid).is_some() {
            self.active.fetch_sub(1, Ordering::Relaxed);
        }
    }
}

/// Render the trace as a span tree: each node carries `name`, `tid`,
/// `start_us`, `dur_us`, `self_us` (duration minus the sum of child
/// durations, clamped at zero) and `children` sorted by start time.
/// Spans whose parent never finished surface as extra roots; multiple
/// roots get wrapped in a synthetic `"trace"` node.
pub fn span_tree_json(buf: &TraceBuf) -> Json {
    let recs = buf.recs();
    let ids: std::collections::HashSet<u64> = recs.iter().map(|r| r.id).collect();
    let mut kids: HashMap<u64, Vec<usize>> = HashMap::new();
    let mut roots: Vec<usize> = Vec::new();
    for (i, r) in recs.iter().enumerate() {
        if r.parent != 0 && ids.contains(&r.parent) {
            kids.entry(r.parent).or_default().push(i);
        } else {
            roots.push(i);
        }
    }
    for v in kids.values_mut() {
        v.sort_by_key(|&i| recs[i].start_us);
    }
    roots.sort_by_key(|&i| recs[i].start_us);
    let root_nodes: Vec<Json> = roots.iter().map(|&i| tree_node(&recs, &kids, i)).collect();
    match root_nodes.len() {
        1 => root_nodes.into_iter().next().unwrap(),
        _ => Json::obj(vec![
            ("name", Json::str("trace")),
            ("children", Json::arr(root_nodes)),
        ]),
    }
}

fn tree_node(recs: &[SpanRec], kids: &HashMap<u64, Vec<usize>>, i: usize) -> Json {
    let r = &recs[i];
    let dur = r.end_us.saturating_sub(r.start_us);
    let mut child_nodes = Vec::new();
    let mut child_dur = 0u64;
    if let Some(children) = kids.get(&r.id) {
        for &j in children {
            child_dur += recs[j].end_us.saturating_sub(recs[j].start_us);
            child_nodes.push(tree_node(recs, kids, j));
        }
    }
    let mut pairs = vec![
        ("name", Json::str(r.name)),
        ("tid", Json::num(r.tid as f64)),
        ("start_us", Json::num(r.start_us as f64)),
        ("dur_us", Json::num(dur as f64)),
        ("self_us", Json::num(dur.saturating_sub(child_dur) as f64)),
        ("children", Json::arr(child_nodes)),
    ];
    if let Some(m) = &r.meta {
        pairs.push(("meta", Json::str(m.clone())));
    }
    Json::obj(pairs)
}

/// Render the trace as a Chrome `trace_event` array (complete `"X"`
/// events): wrap in `{"traceEvents": [...]}` or load the bare array
/// directly in `chrome://tracing` / Perfetto.
pub fn chrome_trace_json(buf: &TraceBuf) -> Json {
    let recs = buf.recs();
    Json::arr(
        recs.iter()
            .map(|r| {
                let mut args = Vec::new();
                if let Some(m) = &r.meta {
                    args.push(("meta", Json::str(m.clone())));
                }
                Json::obj(vec![
                    ("name", Json::str(r.name)),
                    ("ph", Json::str("X")),
                    ("ts", Json::num(r.start_us as f64)),
                    ("dur", Json::num(r.end_us.saturating_sub(r.start_us) as f64)),
                    ("pid", Json::num(buf.trace_id as f64)),
                    ("tid", Json::num(r.tid as f64)),
                    ("args", Json::obj(args)),
                ])
            })
            .collect(),
    )
}

/// Condensed indented text form of the span tree, for the slow-query
/// log. Capped at `max_lines` lines (a final line reports the excess).
pub fn condensed(buf: &TraceBuf, max_lines: usize) -> String {
    let recs = buf.recs();
    let ids: std::collections::HashSet<u64> = recs.iter().map(|r| r.id).collect();
    let mut kids: HashMap<u64, Vec<usize>> = HashMap::new();
    let mut roots: Vec<usize> = Vec::new();
    for (i, r) in recs.iter().enumerate() {
        if r.parent != 0 && ids.contains(&r.parent) {
            kids.entry(r.parent).or_default().push(i);
        } else {
            roots.push(i);
        }
    }
    for v in kids.values_mut() {
        v.sort_by_key(|&i| recs[i].start_us);
    }
    roots.sort_by_key(|&i| recs[i].start_us);
    let mut out = String::new();
    let mut lines = 0usize;
    let mut stack: Vec<(usize, usize)> = roots.iter().rev().map(|&i| (i, 0)).collect();
    let mut skipped = 0usize;
    while let Some((i, depth)) = stack.pop() {
        if lines >= max_lines {
            skipped += 1;
        } else {
            let r = &recs[i];
            let dur = r.end_us.saturating_sub(r.start_us);
            out.push_str(&format!("{:indent$}{} {}us", "", r.name, dur, indent = depth * 2));
            if let Some(m) = &r.meta {
                out.push_str(&format!(" [{m}]"));
            }
            out.push('\n');
            lines += 1;
        }
        if let Some(children) = kids.get(&recs[i].id) {
            for &j in children.iter().rev() {
                stack.push((j, depth + 1));
            }
        }
    }
    if skipped > 0 {
        out.push_str(&format!("… (+{skipped} more spans)\n"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn off_mode_spans_are_inert() {
        let t = Tracer::new(false);
        let root = t.start("query", None, false);
        assert!(!root.is_on());
        assert_eq!(root.trace_id(), 0);
        let child = root.child("decode");
        assert!(!child.is_on());
        child.event("x", None);
        child.end();
        root.end();
        assert!(t.get(None).is_none());
    }

    #[test]
    fn force_overrides_disabled() {
        let t = Tracer::new(false);
        let root = t.start("query", None, true);
        assert!(root.is_on());
        let id = root.trace_id();
        root.end();
        assert_eq!(t.get(Some(id)).unwrap().trace_id, id);
    }

    #[test]
    fn tree_nests_and_self_times_account() {
        let t = Tracer::new(true);
        let root = t.start("query", Some("k=mass".to_string()), false);
        let a = root.child("decode");
        std::thread::sleep(std::time::Duration::from_millis(2));
        a.end();
        let b = root.child("exec");
        std::thread::sleep(std::time::Duration::from_millis(2));
        b.event("failover", Some("w3".to_string()));
        b.end();
        root.end();

        let buf = t.get(None).unwrap();
        assert_eq!(buf.len(), 4); // decode, failover event, exec, root
        let tree = span_tree_json(&buf);
        assert_eq!(tree.get("name").unwrap().as_str(), Some("query"));
        assert_eq!(tree.get("meta").unwrap().as_str(), Some("k=mass"));
        let children = tree.get("children").unwrap().as_arr().unwrap();
        assert_eq!(children.len(), 2);
        assert_eq!(children[0].get("name").unwrap().as_str(), Some("decode"));
        assert_eq!(children[1].get("name").unwrap().as_str(), Some("exec"));
        // Parent intervals contain child intervals.
        let (rs, rd) = (
            tree.get("start_us").unwrap().as_u64().unwrap(),
            tree.get("dur_us").unwrap().as_u64().unwrap(),
        );
        for c in children {
            let cs = c.get("start_us").unwrap().as_u64().unwrap();
            let cd = c.get("dur_us").unwrap().as_u64().unwrap();
            assert!(cs >= rs && cs + cd <= rs + rd);
        }
        // self = dur − Σ child durs.
        let child_sum: u64 = children
            .iter()
            .map(|c| c.get("dur_us").unwrap().as_u64().unwrap())
            .sum();
        let self_us = tree.get("self_us").unwrap().as_u64().unwrap();
        assert_eq!(self_us, rd - child_sum);
    }

    #[test]
    fn spans_cross_threads_with_explicit_parents() {
        let t = Tracer::new(true);
        let root = t.start("query", None, false);
        let handles: Vec<_> = (0..4)
            .map(|i| {
                let parent = root.child("subtask");
                std::thread::spawn(move || {
                    let k = parent.child("fill");
                    k.end();
                    parent.end_meta(format!("part={i}"));
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        root.end();
        let buf = t.get(None).unwrap();
        assert_eq!(buf.len(), 9);
        let tree = span_tree_json(&buf);
        let children = tree.get("children").unwrap().as_arr().unwrap();
        assert_eq!(children.len(), 4);
        for c in children {
            assert_eq!(c.get("name").unwrap().as_str(), Some("subtask"));
            assert_eq!(c.get("children").unwrap().as_arr().unwrap().len(), 1);
        }
    }

    #[test]
    fn ring_evicts_oldest_and_finds_by_id() {
        let t = Tracer::new(true);
        let mut first_id = 0;
        for i in 0..70 {
            let s = t.start("query", None, false);
            if i == 0 {
                first_id = s.trace_id();
            }
            s.end();
        }
        assert!(t.get(Some(first_id)).is_none(), "oldest trace evicted");
        assert!(t.get(None).is_some());
    }

    #[test]
    fn chrome_events_have_required_fields() {
        let t = Tracer::new(true);
        let root = t.start("query", None, false);
        root.child("exec").end();
        root.end();
        let buf = t.get(None).unwrap();
        let events = chrome_trace_json(&buf);
        let arr = events.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        for e in arr {
            assert_eq!(e.get("ph").unwrap().as_str(), Some("X"));
            for k in ["name", "ts", "dur", "pid", "tid"] {
                assert!(e.get(k).is_some(), "missing {k}");
            }
        }
    }

    #[test]
    fn trace_map_attaches_by_query_id() {
        let t = Tracer::new(true);
        let map = TraceMap::new();
        assert!(!map.any());
        assert!(!map.get(7).is_on());
        let root = t.start("query", None, false);
        map.insert(7, root.clone());
        assert!(map.any());
        assert_eq!(map.get(7).trace_id(), root.trace_id());
        assert!(!map.get(8).is_on());
        map.remove(7);
        assert!(!map.any());
        root.end();
    }

    #[test]
    fn buffer_caps_spans_and_counts_dropped() {
        let t = Tracer::new(true);
        let root = t.start("query", None, false);
        for _ in 0..MAX_SPANS + 10 {
            root.event("tick", None);
        }
        root.end();
        let buf = t.get(None).unwrap();
        assert_eq!(buf.len(), MAX_SPANS);
        assert_eq!(buf.dropped(), 11); // 10 excess events + the root end
    }

    #[test]
    fn condensed_indents_and_caps() {
        let t = Tracer::new(true);
        let root = t.start("query", None, false);
        let c = root.child_meta("exec", "ds=dy".to_string());
        c.end();
        root.end();
        let buf = t.get(None).unwrap();
        let text = condensed(&buf, 100);
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].starts_with("query "));
        assert!(lines[1].starts_with("  exec "));
        assert!(lines[1].contains("[ds=dy]"));
        let capped = condensed(&buf, 1);
        assert!(capped.contains("(+1 more spans)"));
    }
}
