//! Object-materialization baselines — the slow paths of Table 1 / Figure 1.
//!
//! These deliberately reproduce *why* traditional frameworks are slow for
//! query-sized payloads:
//!   * `FrameworkSim` — a CMSSW-like module pipeline: every branch loaded,
//!     every event materialized as a heap object tree, modules invoked
//!     through dynamic dispatch (Table 1 rung 1).
//!   * `heap_objects` — materialize each particle as a separately allocated
//!     heap object, then run the analysis function (rung 4).
//!   * `stack_objects` — materialize particles by value into a reused
//!     buffer (rung 5).
//! The contrast with `columnar_exec` (no materialization at all) is the
//! paper's two final orders of magnitude.

use crate::columnar::arrays::ColumnSet;
use crate::columnar::explode::{materialize, Value};
use crate::engine::query::QueryKind;
use crate::hist::H1;

/// A materialized particle (stack flavor).
#[derive(Clone, Copy, Debug, Default)]
pub struct Particle {
    pub pt: f32,
    pub eta: f32,
    pub phi: f32,
}

/// An event with heap-allocated particle objects — each particle is its own
/// allocation, as in frameworks where collections hold pointers.
pub struct HeapEvent {
    pub particles: Vec<Box<Particle>>,
}

/// An event with by-value particles.
pub struct StackEvent {
    pub particles: Vec<Particle>,
}

fn leaf<'a>(cs: &'a ColumnSet, list: &str, attr: &str) -> Result<&'a [f32], String> {
    cs.leaf(&format!("{list}.{attr}"))
        .ok_or_else(|| format!("no leaf '{list}.{attr}'"))?
        .as_f32()
        .ok_or_else(|| format!("'{list}.{attr}' not f32"))
}

/// Materialize all events with heap-allocated particles (loads only the
/// attributes the function needs — this is the "selective + objects" path).
pub fn materialize_heap(cs: &ColumnSet, list: &str) -> Result<Vec<HeapEvent>, String> {
    let off = cs.offsets_of(list).ok_or_else(|| format!("no list '{list}'"))?;
    let pt = leaf(cs, list, "pt")?;
    let eta = leaf(cs, list, "eta").unwrap_or(&[]);
    let phi = leaf(cs, list, "phi").unwrap_or(&[]);
    let mut events = Vec::with_capacity(cs.n_events);
    for w in off.windows(2) {
        let mut particles = Vec::with_capacity((w[1] - w[0]) as usize);
        for k in w[0] as usize..w[1] as usize {
            particles.push(Box::new(Particle {
                pt: pt[k],
                eta: eta.get(k).copied().unwrap_or(0.0),
                phi: phi.get(k).copied().unwrap_or(0.0),
            }));
        }
        events.push(HeapEvent { particles });
    }
    Ok(events)
}

/// Materialize with by-value particles.
pub fn materialize_stack(cs: &ColumnSet, list: &str) -> Result<Vec<StackEvent>, String> {
    let off = cs.offsets_of(list).ok_or_else(|| format!("no list '{list}'"))?;
    let pt = leaf(cs, list, "pt")?;
    let eta = leaf(cs, list, "eta").unwrap_or(&[]);
    let phi = leaf(cs, list, "phi").unwrap_or(&[]);
    let mut events = Vec::with_capacity(cs.n_events);
    for w in off.windows(2) {
        let mut particles = Vec::with_capacity((w[1] - w[0]) as usize);
        for k in w[0] as usize..w[1] as usize {
            particles.push(Particle {
                pt: pt[k],
                eta: eta.get(k).copied().unwrap_or(0.0),
                phi: phi.get(k).copied().unwrap_or(0.0),
            });
        }
        events.push(StackEvent { particles });
    }
    Ok(events)
}

macro_rules! analysis_over {
    ($kind:expr, $events:expr, $hist:expr, $get:expr) => {{
        match $kind {
            QueryKind::MaxPt => {
                for ev in $events {
                    let mut maximum = f32::NEG_INFINITY;
                    let mut any = false;
                    for p in ev.particles.iter() {
                        let p = $get(p);
                        if p.pt > maximum {
                            maximum = p.pt;
                        }
                        any = true;
                    }
                    if any {
                        $hist.fill(maximum as f64);
                    }
                }
            }
            QueryKind::EtaBest => {
                for ev in $events {
                    let mut maximum = f32::NEG_INFINITY;
                    let mut best: Option<f32> = None;
                    for p in ev.particles.iter() {
                        let p = $get(p);
                        if p.pt > maximum {
                            maximum = p.pt;
                            best = Some(p.eta);
                        }
                    }
                    if let Some(eta) = best {
                        $hist.fill(eta as f64);
                    }
                }
            }
            QueryKind::PtSumPairs => {
                for ev in $events {
                    let n = ev.particles.len();
                    for i in 0..n {
                        for j in i + 1..n {
                            let a = $get(&ev.particles[i]);
                            let b = $get(&ev.particles[j]);
                            $hist.fill((a.pt + b.pt) as f64);
                        }
                    }
                }
            }
            QueryKind::MassPairs => {
                for ev in $events {
                    let n = ev.particles.len();
                    for i in 0..n {
                        for j in i + 1..n {
                            let a = $get(&ev.particles[i]);
                            let b = $get(&ev.particles[j]);
                            let m2 = 2.0 * (a.pt as f64) * (b.pt as f64)
                                * (((a.eta - b.eta) as f64).cosh()
                                    - ((a.phi - b.phi) as f64).cos());
                            $hist.fill(m2.max(0.0).sqrt());
                        }
                    }
                }
            }
            QueryKind::FlatHist => {
                for ev in $events {
                    for p in ev.particles.iter() {
                        $hist.fill($get(p).pt as f64);
                    }
                }
            }
        }
    }};
}

/// Run an analysis function over heap-materialized events.
pub fn run_heap(kind: QueryKind, events: &[HeapEvent], hist: &mut H1) {
    analysis_over!(kind, events, hist, |p: &Box<Particle>| **p)
}

/// Run an analysis function over stack-materialized events.
pub fn run_stack(kind: QueryKind, events: &[StackEvent], hist: &mut H1) {
    analysis_over!(kind, events, hist, |p: &Particle| *p)
}

// ---------------------------------------------------------------------
// Full-framework simulation (Table 1, rung 1)
// ---------------------------------------------------------------------

/// A framework "module" — invoked through dynamic dispatch per event, like
/// an EDAnalyzer. Modules receive the fully materialized generic event.
pub trait Module {
    fn process(&mut self, event: &Value);
}

/// Bookkeeping modules that real frameworks run regardless of the analysis
/// payload: provenance tracking, trigger accounting, monitoring.
pub struct ProvenanceModule {
    pub records: u64,
}

impl Module for ProvenanceModule {
    fn process(&mut self, event: &Value) {
        // Walk the whole event tree, as provenance/monitoring code does.
        fn walk(v: &Value, n: &mut u64) {
            match v {
                Value::List(items) => {
                    for i in items {
                        walk(i, n);
                    }
                }
                Value::Rec(fields) => {
                    for (_, f) in fields {
                        walk(f, n);
                    }
                }
                _ => *n += 1,
            }
        }
        walk(event, &mut self.records);
    }
}

pub struct TriggerAccountingModule {
    pub passed: u64,
}

impl Module for TriggerAccountingModule {
    fn process(&mut self, event: &Value) {
        // Looks at the leading jet/muon pt, as a trigger monitor would.
        let list = event
            .get("jets")
            .or_else(|| event.get("muons"))
            .and_then(|l| l.as_list());
        if let Some(items) = list {
            if let Some(first) = items.first() {
                if first.get("pt").and_then(|p| p.as_f64()).unwrap_or(0.0) > 30.0 {
                    self.passed += 1;
                }
            }
        }
    }
}

/// The full-framework path: materialize EVERY branch of EVERY event into a
/// generic heap object tree, run the module chain, then run the analysis.
pub struct FrameworkSim {
    modules: Vec<Box<dyn Module>>,
}

impl Default for FrameworkSim {
    fn default() -> Self {
        Self::new()
    }
}

impl FrameworkSim {
    pub fn new() -> FrameworkSim {
        FrameworkSim {
            modules: vec![
                Box::new(ProvenanceModule { records: 0 }),
                Box::new(TriggerAccountingModule { passed: 0 }),
            ],
        }
    }

    /// Process the partition the way a full framework would, then fill the
    /// query histogram from the materialized objects.
    pub fn run(
        &mut self,
        cs: &ColumnSet,
        list: &str,
        kind: QueryKind,
        hist: &mut H1,
    ) -> Result<(), String> {
        for i in 0..cs.n_events {
            // GetEntry: every branch decoded into a generic object tree.
            let event = materialize(cs, i)?;
            for m in self.modules.iter_mut() {
                m.process(&event);
            }
            // The analysis function, via the generic object API.
            let items = event
                .get(list)
                .and_then(|l| l.as_list())
                .ok_or_else(|| format!("no list '{list}'"))?;
            fill_from_generic(kind, items, hist);
        }
        Ok(())
    }
}

fn fill_from_generic(kind: QueryKind, items: &[Value], hist: &mut H1) {
    let attr = |v: &Value, name: &str| v.get(name).and_then(|x| x.as_f64()).unwrap_or(0.0);
    match kind {
        QueryKind::MaxPt => {
            let mut maximum = f64::NEG_INFINITY;
            for it in items {
                let p = attr(it, "pt");
                if p > maximum {
                    maximum = p;
                }
            }
            if !items.is_empty() {
                hist.fill(maximum);
            }
        }
        QueryKind::EtaBest => {
            let mut maximum = f64::NEG_INFINITY;
            let mut best = None;
            for it in items {
                let p = attr(it, "pt");
                if p > maximum {
                    maximum = p;
                    best = Some(attr(it, "eta"));
                }
            }
            if let Some(eta) = best {
                hist.fill(eta);
            }
        }
        QueryKind::PtSumPairs => {
            for i in 0..items.len() {
                for j in i + 1..items.len() {
                    hist.fill(attr(&items[i], "pt") + attr(&items[j], "pt"));
                }
            }
        }
        QueryKind::MassPairs => {
            for i in 0..items.len() {
                for j in i + 1..items.len() {
                    let a = &items[i];
                    let b = &items[j];
                    let (p1, e1, f1) = (attr(a, "pt"), attr(a, "eta"), attr(a, "phi"));
                    let (p2, e2, f2) = (attr(b, "pt"), attr(b, "eta"), attr(b, "phi"));
                    let m2 = 2.0 * p1 * p2 * ((e1 - e2).cosh() - (f1 - f2).cos());
                    hist.fill(m2.max(0.0).sqrt());
                }
            }
        }
        QueryKind::FlatHist => {
            for it in items {
                hist.fill(attr(it, "pt"));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::generate_drellyan;
    use crate::engine::columnar_exec;

    /// All object backends must agree exactly with the columnar executor.
    #[test]
    fn baselines_match_columnar() {
        let cs = generate_drellyan(1500, 21);
        for kind in QueryKind::ALL {
            let (lo, hi) = kind.default_binning();
            let mut h_col = H1::new(64, lo, hi);
            columnar_exec::run(kind, &cs, "muons", &mut h_col).unwrap();

            let heap = materialize_heap(&cs, "muons").unwrap();
            let mut h_heap = H1::new(64, lo, hi);
            run_heap(kind, &heap, &mut h_heap);

            let stack = materialize_stack(&cs, "muons").unwrap();
            let mut h_stack = H1::new(64, lo, hi);
            run_stack(kind, &stack, &mut h_stack);

            let mut fw = FrameworkSim::new();
            let mut h_fw = H1::new(64, lo, hi);
            fw.run(&cs, "muons", kind, &mut h_fw).unwrap();

            assert_eq!(h_heap.bins, h_col.bins, "{kind:?} heap");
            assert_eq!(h_stack.bins, h_col.bins, "{kind:?} stack");
            // Framework path goes through f64 generic values; identical
            // fills but compare totals + bins loosely for f32→f64 effects.
            assert_eq!(h_fw.total(), h_col.total(), "{kind:?} framework total");
            let diff: f64 = h_fw
                .bins
                .iter()
                .zip(&h_col.bins)
                .map(|(a, b)| (a - b).abs())
                .sum();
            assert!(diff <= 4.0, "{kind:?} framework bins diff {diff}");
        }
    }
}
