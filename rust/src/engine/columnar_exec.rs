//! Hand-written columnar executors — the transformed-code endpoint.
//!
//! These are exactly the loops the paper's code transformation *produces*
//! (section 3): flat loops over offsets and content arrays, no objects, no
//! allocation, sequential memory access. They serve three roles:
//!   * the fast native backend of the query engine,
//!   * the target semantics the queryir transform is tested against,
//!   * the "250 MHz minimal for-loop" rung of Table 1.

use crate::columnar::arrays::ColumnSet;
use crate::engine::query::QueryKind;
use crate::hist::H1;

/// Run a query kind over an exploded partition, filling `hist`.
pub fn run(
    kind: QueryKind,
    cs: &ColumnSet,
    list: &str,
    hist: &mut H1,
) -> Result<(), String> {
    let off = cs
        .offsets_of(list)
        .ok_or_else(|| format!("no list '{list}'"))?;
    let leaf = |attr: &str| -> Result<&[f32], String> {
        cs.leaf(&format!("{list}.{attr}"))
            .ok_or_else(|| format!("no leaf '{list}.{attr}'"))?
            .as_f32()
            .ok_or_else(|| format!("'{list}.{attr}' not f32"))
    };
    match kind {
        QueryKind::MaxPt => max_pt(off, leaf("pt")?, hist),
        QueryKind::EtaBest => eta_best(off, leaf("pt")?, leaf("eta")?, hist),
        QueryKind::PtSumPairs => ptsum_pairs(off, leaf("pt")?, hist),
        QueryKind::MassPairs => {
            mass_pairs(off, leaf("pt")?, leaf("eta")?, leaf("phi")?, hist)
        }
        QueryKind::FlatHist => flat_hist(leaf("pt")?, hist),
    }
    Ok(())
}

/// max p_T — transformed form of Table 3, column 1.
pub fn max_pt(offsets: &[i64], pt: &[f32], hist: &mut H1) {
    for w in offsets.windows(2) {
        let (lo, hi) = (w[0] as usize, w[1] as usize);
        if lo == hi {
            continue;
        }
        let mut maximum = f32::NEG_INFINITY;
        for &p in &pt[lo..hi] {
            if p > maximum {
                maximum = p;
            }
        }
        hist.fill(maximum as f64);
    }
}

/// eta of best by p_T — transformed form of Table 3, column 2.
pub fn eta_best(offsets: &[i64], pt: &[f32], eta: &[f32], hist: &mut H1) {
    for w in offsets.windows(2) {
        let (lo, hi) = (w[0] as usize, w[1] as usize);
        let mut maximum = f32::NEG_INFINITY;
        let mut best = usize::MAX;
        for k in lo..hi {
            if pt[k] > maximum {
                maximum = pt[k];
                best = k;
            }
        }
        if best != usize::MAX {
            hist.fill(eta[best] as f64);
        }
    }
}

/// p_T sum of pairs — transformed form of Table 3, column 3.
pub fn ptsum_pairs(offsets: &[i64], pt: &[f32], hist: &mut H1) {
    for w in offsets.windows(2) {
        let (lo, hi) = (w[0] as usize, w[1] as usize);
        for i in lo..hi {
            for j in i + 1..hi {
                hist.fill((pt[i] + pt[j]) as f64);
            }
        }
    }
}

/// mass of pairs — transformed form of Table 3, column 4.
pub fn mass_pairs(offsets: &[i64], pt: &[f32], eta: &[f32], phi: &[f32], hist: &mut H1) {
    for w in offsets.windows(2) {
        let (lo, hi) = (w[0] as usize, w[1] as usize);
        for i in lo..hi {
            for j in i + 1..hi {
                let m2 = 2.0 * (pt[i] as f64) * (pt[j] as f64)
                    * (((eta[i] - eta[j]) as f64).cosh() - ((phi[i] - phi[j]) as f64).cos());
                hist.fill(m2.max(0.0).sqrt());
            }
        }
    }
}

/// Flat fill of every item — Table 1's payload, and (without the histogram
/// bin lookup replaced by anything fancier) the "minimal for loop" rung.
pub fn flat_hist(content: &[f32], hist: &mut H1) {
    for &x in content {
        hist.fill(x as f64);
    }
}

/// Table-1 rung 6: the truly minimal in-memory loop — bins directly into a
/// local fixed array with no H1 bookkeeping, the fastest this machine can
/// histogram at all. Returns the bins so the optimizer can't drop the work.
pub fn minimal_loop(content: &[f32], lo: f32, hi: f32, bins: &mut [u64]) {
    let scale = bins.len() as f32 / (hi - lo);
    for &x in content {
        let i = ((x - lo) * scale) as i64;
        if (0..bins.len() as i64).contains(&i) {
            bins[i as usize] += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::generate_drellyan;
    use crate::engine::query::QueryKind;

    #[test]
    fn all_kinds_run_on_dy() {
        let cs = generate_drellyan(2000, 11);
        for kind in QueryKind::ALL {
            let (lo, hi) = kind.default_binning();
            let mut h = H1::new(64, lo, hi);
            run(kind, &cs, "muons", &mut h).unwrap();
            if kind != QueryKind::EtaBest {
                assert!(h.total() > 0.0, "{kind:?} filled nothing");
            }
        }
    }

    #[test]
    fn max_pt_by_hand() {
        let off = [0i64, 2, 2, 3];
        let pt = [10.0f32, 30.0, 7.0];
        let mut h = H1::new(4, 0.0, 40.0);
        max_pt(&off, &pt, &mut h);
        assert_eq!(h.total(), 2.0); // empty event skipped
        assert_eq!(h.bins[3], 1.0); // 30 → bin 3
        assert_eq!(h.bins[0], 1.0); // 7 → bin 0
    }

    #[test]
    fn pair_counts() {
        let off = [0i64, 3, 4]; // 3 pairs + 0 pairs
        let pt = [1.0f32, 2.0, 3.0, 9.0];
        let mut h = H1::new(8, 0.0, 8.0);
        ptsum_pairs(&off, &pt, &mut h);
        assert_eq!(h.total(), 3.0);
    }

    #[test]
    fn mass_of_back_to_back() {
        let off = [0i64, 2];
        let pt = [45.6f32, 45.6];
        let eta = [0.0f32, 0.0];
        let phi = [0.0f32, std::f32::consts::PI];
        let mut h = H1::new(64, 0.0, 128.0);
        mass_pairs(&off, &pt, &eta, &phi, &mut h);
        assert_eq!(h.total(), 1.0);
        assert!((h.mean() - 91.2).abs() < 0.1);
    }

    #[test]
    fn minimal_loop_matches_h1_in_range() {
        let data: Vec<f32> = (0..1000).map(|i| (i % 97) as f32).collect();
        let mut bins = vec![0u64; 64];
        minimal_loop(&data, 0.0, 97.0, &mut bins);
        let mut h = H1::new(64, 0.0, 97.0);
        flat_hist(&data, &mut h);
        let total: u64 = bins.iter().sum();
        assert_eq!(total as f64, h.in_range());
    }
}
