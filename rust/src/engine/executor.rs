//! Per-partition execution dispatch: one enum over every backend so the
//! coordinator, server, benches and examples pick a path with one value.

use crate::columnar::arrays::ColumnSet;
use crate::engine::compiled_exec::CompiledTapeBackend;
use crate::engine::query::Query;
use crate::engine::{columnar_exec, object_baseline};
use crate::hist::{Sink, H1};
use crate::index::ZoneMap;
use crate::queryir::lower::IndexedRun;

#[cfg(feature = "pjrt")]
pub use pjrt_backend::PjrtBackend;

/// The PJRT execution path (behind the `pjrt` cargo feature): load AOT
/// artifacts and execute them through an XLA binding.
#[cfg(feature = "pjrt")]
mod pjrt_backend {
    use crate::runtime::ArtifactRegistry;
    use std::cell::RefCell;
    use std::collections::HashMap;
    use std::path::PathBuf;
    use std::rc::Rc;
    use std::sync::Arc;

    thread_local! {
        /// PJRT clients are not Send (the xla crate wraps Rc internally), so
        /// each worker thread owns its own registry — mirroring a deployment
        /// where every worker process has its own runtime. Keyed by artifact
        /// dir; compiled executables are cached inside the registry.
        static TL_REGISTRIES: RefCell<HashMap<PathBuf, Rc<ArtifactRegistry>>> =
            RefCell::new(HashMap::new());
    }

    /// Handle to the AOT artifacts, shareable across threads.
    #[derive(Clone, Debug)]
    pub struct PjrtBackend {
        pub artifact_dir: Arc<PathBuf>,
    }

    impl PjrtBackend {
        pub fn new(dir: impl Into<PathBuf>) -> PjrtBackend {
            PjrtBackend {
                artifact_dir: Arc::new(dir.into()),
            }
        }

        /// This thread's registry (created + compiled on first use).
        pub fn registry(&self) -> Result<Rc<ArtifactRegistry>, String> {
            TL_REGISTRIES.with(|map| {
                let mut map = map.borrow_mut();
                if let Some(r) = map.get(self.artifact_dir.as_ref()) {
                    return Ok(r.clone());
                }
                let reg = Rc::new(ArtifactRegistry::open(self.artifact_dir.as_ref())?);
                map.insert((*self.artifact_dir).clone(), reg.clone());
                Ok(reg)
            })
        }
    }
}

/// How to execute a query over a partition. One value selects the whole
/// execution strategy for cluster workers, the TCP server, the CLI and
/// the benches; `Backend::CompiledTape` is the production path (closure
/// graph + chunked mask-and-fill kernels, see `docs/ARCHITECTURE.md`),
/// the rest are reference implementations and Table-1 baselines.
#[derive(Clone, Debug)]
pub enum Backend {
    /// Hand-written flat loops (the transformed-code endpoint).
    Columnar,
    /// Query-language source → flat tape → compiled closure loops. Runs any
    /// query the language can express at near-handwritten speed; programs
    /// compile once per process (shared cache).
    CompiledTape(CompiledTapeBackend),
    /// Heap-object materialization then object loops.
    HeapObjects,
    /// Stack-object materialization then object loops.
    StackObjects,
    /// Full framework simulation (all branches, module chain).
    FrameworkSim,
    /// AOT-compiled Pallas/JAX artifact via PJRT.
    #[cfg(feature = "pjrt")]
    Pjrt(PjrtBackend),
}

impl Backend {
    /// The compiled-tape backend with a fresh (shareable) compile cache.
    pub fn compiled() -> Backend {
        Backend::CompiledTape(CompiledTapeBackend::new())
    }

    /// The compiled-tape backend with morsel-driven intra-partition
    /// parallelism: every partition run uses up to `threads` cores
    /// (0 = all available). See `queryir::lower::run_parallel`.
    pub fn compiled_parallel(threads: usize) -> Backend {
        Backend::CompiledTape(CompiledTapeBackend::new().with_parallelism(
            crate::queryir::lower::ParallelCfg {
                threads,
                morsel_events: 0,
            },
        ))
    }

    pub fn name(&self) -> &'static str {
        match self {
            Backend::Columnar => "columnar",
            Backend::CompiledTape(_) => "compiled-tape",
            Backend::HeapObjects => "heap-objects",
            Backend::StackObjects => "stack-objects",
            Backend::FrameworkSim => "framework-sim",
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(_) => "pjrt",
        }
    }

    /// `run` with a partition zone map: the compiled-tape backend skips
    /// chunks the query's cut provably rejects (bit-identical results, see
    /// `queryir::lower::run_parallel_indexed`); every other backend
    /// ignores the map and scans. Cluster workers call this so chunk
    /// skipping engages wherever partitions carry zone maps.
    pub fn run_indexed(
        &self,
        query: &Query,
        cs: &ColumnSet,
        zm: Option<&ZoneMap>,
        hist: &mut H1,
    ) -> Result<IndexedRun, String> {
        match self {
            Backend::CompiledTape(ct) => ct.run_indexed(query, cs, zm, hist),
            other => other.run(query, cs, hist).map(|_| IndexedRun::default()),
        }
    }

    /// Run several queries over one partition in a single shared scan.
    /// The compiled-tape backend streams every query's kernel through the
    /// same event windows so the partition's columns are read once
    /// (`CompiledTapeBackend::run_fused_indexed`); the result in
    /// `hists[i]` is bit-identical to `run_indexed` for query `i` alone.
    /// Other backends fall back to running the queries back-to-back —
    /// still one partition fetch, just no cache sharing.
    pub fn run_fused(
        &self,
        queries: &[&Query],
        cs: &ColumnSet,
        zm: Option<&ZoneMap>,
        hists: &mut [H1],
    ) -> Result<Vec<IndexedRun>, String> {
        if queries.len() != hists.len() {
            return Err(format!(
                "run_fused: {} queries but {} histograms",
                queries.len(),
                hists.len()
            ));
        }
        match self {
            Backend::CompiledTape(ct) => ct.run_fused_indexed(queries, cs, zm, hists),
            other => {
                let mut reps = Vec::with_capacity(queries.len());
                for (q, h) in queries.iter().zip(hists.iter_mut()) {
                    reps.push(other.run_indexed(q, cs, zm, h)?);
                }
                Ok(reps)
            }
        }
    }

    /// `run_indexed` for the full statement set: aux sinks
    /// (`fill2`/`profile`/`fill_vars`) fill in the same pass and come back
    /// alongside the report. Only the compiled-tape backend executes
    /// aux-bearing programs; the others return an empty vector for
    /// aux-free queries and surface their tier's group-API error
    /// otherwise.
    pub fn run_group_indexed(
        &self,
        query: &Query,
        cs: &ColumnSet,
        zm: Option<&ZoneMap>,
        hist: &mut H1,
    ) -> Result<(Vec<Sink>, IndexedRun), String> {
        match self {
            Backend::CompiledTape(ct) => ct.run_group_indexed(query, cs, zm, hist),
            other => other
                .run_indexed(query, cs, zm, hist)
                .map(|rep| (Vec::new(), rep)),
        }
    }

    /// `run_fused` for the full statement set: per-query aux sinks fill
    /// from the shared scan (compiled-tape) or from back-to-back group
    /// runs (other backends).
    pub fn run_fused_group(
        &self,
        queries: &[&Query],
        cs: &ColumnSet,
        zm: Option<&ZoneMap>,
        hists: &mut [H1],
    ) -> Result<(Vec<Vec<Sink>>, Vec<IndexedRun>), String> {
        if queries.len() != hists.len() {
            return Err(format!(
                "run_fused_group: {} queries but {} histograms",
                queries.len(),
                hists.len()
            ));
        }
        match self {
            Backend::CompiledTape(ct) => ct.run_fused_group_indexed(queries, cs, zm, hists),
            other => {
                let mut auxes = Vec::with_capacity(queries.len());
                let mut reps = Vec::with_capacity(queries.len());
                for (q, h) in queries.iter().zip(hists.iter_mut()) {
                    let (aux, rep) = other.run_group_indexed(q, cs, zm, h)?;
                    auxes.push(aux);
                    reps.push(rep);
                }
                Ok((auxes, reps))
            }
        }
    }

    /// Chunk-skipping counters, when this backend keeps them
    /// (compiled-tape only; shared across all clones).
    pub fn zone_counters(&self) -> Option<IndexedRun> {
        match self {
            Backend::CompiledTape(ct) => Some(ct.zone_stats()),
            _ => None,
        }
    }

    /// Execute `query` over one exploded partition, accumulating into
    /// `hist`.
    pub fn run(&self, query: &Query, cs: &ColumnSet, hist: &mut H1) -> Result<(), String> {
        // Free-form source queries run through the code-transformation
        // pipeline; only the backends that implement it accept them.
        if let Some(src) = &query.source {
            return match self {
                Backend::CompiledTape(ct) => ct.run_source(src, cs, hist),
                Backend::Columnar => crate::queryir::run_transformed(src, cs, hist),
                other => Err(format!(
                    "backend '{}' cannot execute query-language source",
                    other.name()
                )),
            };
        }
        match self {
            Backend::Columnar => columnar_exec::run(query.kind, cs, &query.list, hist),
            Backend::CompiledTape(ct) => ct.run(query, cs, hist),
            Backend::HeapObjects => {
                let events = object_baseline::materialize_heap(cs, &query.list)?;
                object_baseline::run_heap(query.kind, &events, hist);
                Ok(())
            }
            Backend::StackObjects => {
                let events = object_baseline::materialize_stack(cs, &query.list)?;
                object_baseline::run_stack(query.kind, &events, hist);
                Ok(())
            }
            Backend::FrameworkSim => {
                object_baseline::FrameworkSim::new().run(cs, &query.list, query.kind, hist)
            }
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(pj) => {
                use crate::runtime::{PaddedPartition, QueryExecutable};
                let reg = pj.registry()?;
                let exe = QueryExecutable::new(&reg, query.kind.artifact())?;
                let shape = exe.shape();
                let leaves = query.leaf_paths();
                let leaf_refs: Vec<&str> = leaves.iter().map(|s| s.as_str()).collect();
                // The artifact takes at most shape.n_events events; larger
                // partitions are processed in chunks.
                if cs.n_events <= shape.n_events
                    && cs.leaf(&leaves[0]).map(|a| a.len()).unwrap_or(0) <= shape.content_cap
                {
                    let part =
                        PaddedPartition::from_columns(cs, &query.list, &leaf_refs, shape)?;
                    exe.run(&part, query.lo, query.hi, hist)
                } else {
                    for chunk in cs.partition(shape.n_events) {
                        let part = PaddedPartition::from_columns(
                            &chunk,
                            &query.list,
                            &leaf_refs,
                            shape,
                        )?;
                        exe.run(&part, query.lo, query.hi, hist)?;
                    }
                    Ok(())
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::generate_drellyan;
    use crate::engine::query::QueryKind;

    #[test]
    fn non_pjrt_backends_agree() {
        let cs = generate_drellyan(800, 5);
        for kind in [QueryKind::MaxPt, QueryKind::MassPairs] {
            let q = Query::new(kind, "dy", "muons");
            let mut base = H1::new(q.n_bins, q.lo, q.hi);
            Backend::Columnar.run(&q, &cs, &mut base).unwrap();
            for be in [Backend::HeapObjects, Backend::StackObjects] {
                let mut h = H1::new(q.n_bins, q.lo, q.hi);
                be.run(&q, &cs, &mut h).unwrap();
                assert_eq!(h.bins, base.bins, "{kind:?} {be:?}");
            }
            // The compiled tape agrees on totals; pair-mass bins may drift
            // by an ulp against the f32-subtracting hand-written loops.
            let mut h = H1::new(q.n_bins, q.lo, q.hi);
            Backend::compiled().run(&q, &cs, &mut h).unwrap();
            assert_eq!(h.total(), base.total(), "{kind:?} compiled-tape");
        }
    }

    #[test]
    fn parallel_compiled_backend_agrees() {
        // 20k events = several default-size morsels, so the parallel path
        // actually engages.
        let cs = generate_drellyan(20_000, 7);
        let q = Query::new(QueryKind::MassPairs, "dy", "muons");
        let mut seq = H1::new(q.n_bins, q.lo, q.hi);
        Backend::compiled().run(&q, &cs, &mut seq).unwrap();
        let mut par = H1::new(q.n_bins, q.lo, q.hi);
        Backend::compiled_parallel(4).run(&q, &cs, &mut par).unwrap();
        assert_eq!(seq.bins, par.bins);
        assert_eq!(seq.count, par.count);
    }

    #[test]
    fn source_queries_dispatch() {
        let cs = generate_drellyan(300, 6);
        let src = "for event in dataset:\n    for m in event.muons:\n        fill(m.pt)\n";
        let q = Query::from_source(src, "dy");
        let mut h1 = H1::new(q.n_bins, q.lo, q.hi);
        Backend::compiled().run(&q, &cs, &mut h1).unwrap();
        let mut h2 = H1::new(q.n_bins, q.lo, q.hi);
        Backend::Columnar.run(&q, &cs, &mut h2).unwrap();
        assert_eq!(h1.bins, h2.bins);
        assert!(h1.total() > 0.0);
        // Object baselines reject source queries cleanly.
        let mut h3 = H1::new(q.n_bins, q.lo, q.hi);
        assert!(Backend::HeapObjects.run(&q, &cs, &mut h3).is_err());
    }
}
