//! Single-node query engine: query model, backend dispatch, the baseline
//! ladder of Table 1 and the executors behind Figure 1.

pub mod columnar_exec;
pub mod compiled_exec;
pub mod executor;
pub mod object_baseline;
pub mod query;

pub use compiled_exec::CompiledTapeBackend;
pub use executor::Backend;
pub use query::{Query, QueryKind};
