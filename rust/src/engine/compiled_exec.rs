//! The compiled-tape execution backend (`Backend::CompiledTape`).
//!
//! Bridges the query language to the engine: a query — either a built-in
//! `QueryKind` (rendered to query-language source over the requested list)
//! or free-form source text — is parsed, transformed to a flat tape and
//! lowered once (`queryir::lower`), then the compiled program is reused for
//! every partition. The compile cache is shared behind `Arc`, so cloning
//! the backend into every cluster worker means each distinct program is
//! compiled exactly once per process, not once per worker or per partition.
//!
//! This closes the gap the hand-written `columnar_exec` left open: new
//! physics queries no longer need a Rust function per query — any
//! query-language program runs at compiled-loop speed. Cut-based and
//! multi-`fill` bodies included: batchable shapes — fused single-list
//! bodies, loop-free per-event bodies (dynamic `muons[n-1]`-style gathers
//! included), and `range(len)` pair nests over one list *or two different
//! lists* — lower to the chunked mask-and-fill batch kernels
//! (`kernel_info` reports which path, and which lane family, a source
//! query takes). AGC-style bodies with `fill2`/`profile`/`fill_vars`
//! statements run through the `*_group` entry points, which build and
//! return the query's aux sinks alongside the primary histogram.
//! Partitions are **not** necessarily scanned in full: when
//! a zone map is supplied (`run_indexed`), chunks the query's cut provably
//! rejects are skipped and provably-accepted chunks run unmasked, with
//! process-wide counters (`zone_stats`) feeding the server's `stats` op.
//! The whole pipeline is documented in `docs/ARCHITECTURE.md`; the
//! accepted source language in `docs/QUERY_LANGUAGE.md`.

use crate::columnar::arrays::ColumnSet;
use crate::engine::query::{Query, QueryKind};
use crate::hist::{Sink, H1};
use crate::index::ZoneMap;
use crate::queryir::{self, lower};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

/// Query-language source for a built-in query kind over an arbitrary list.
/// Semantically identical to the hand-written loops in `columnar_exec` (and
/// to `queryir::table3` when `list == "muons"`).
pub fn source_for(kind: QueryKind, list: &str) -> String {
    match kind {
        QueryKind::MaxPt => format!(
            "for event in dataset:\n    \
             maximum = 0.0\n    \
             n = len(event.{list})\n    \
             for item in event.{list}:\n        \
             if item.pt > maximum:\n            \
             maximum = item.pt\n    \
             if n > 0:\n        \
             fill(maximum)\n"
        ),
        QueryKind::EtaBest => format!(
            "for event in dataset:\n    \
             maximum = 0.0\n    \
             found = 0\n    \
             eta = 0.0\n    \
             for item in event.{list}:\n        \
             if item.pt > maximum:\n            \
             maximum = item.pt\n            \
             eta = item.eta\n            \
             found = 1\n    \
             if found > 0:\n        \
             fill(eta)\n"
        ),
        QueryKind::PtSumPairs => format!(
            "for event in dataset:\n    \
             n = len(event.{list})\n    \
             for i in range(n):\n        \
             for j in range(i + 1, n):\n            \
             a = event.{list}[i]\n            \
             b = event.{list}[j]\n            \
             fill(a.pt + b.pt)\n"
        ),
        QueryKind::MassPairs => format!(
            "for event in dataset:\n    \
             n = len(event.{list})\n    \
             for i in range(n):\n        \
             for j in range(i + 1, n):\n            \
             a = event.{list}[i]\n            \
             b = event.{list}[j]\n            \
             mass = sqrt(2 * a.pt * b.pt * (cosh(a.eta - b.eta) - cos(a.phi - b.phi)))\n            \
             fill(mass)\n"
        ),
        QueryKind::FlatHist => format!(
            "for event in dataset:\n    \
             for item in event.{list}:\n        \
             fill(item.pt)\n"
        ),
    }
}

/// The backend: a process-wide compile cache keyed by (source, schema).
/// Full strings as keys (not digests): query source arrives from untrusted
/// clients, and a hash-only key would let collisions execute the wrong
/// program.
///
/// `parallel` configures intra-partition morsel execution: with
/// `threads > 1` (or 0 = all cores) every partition run is split into
/// cache-sized morsels spread over a scoped thread pool
/// (`lower::run_parallel`). The default stays sequential because cluster
/// workers already parallelize across partitions; single-worker and
/// single-partition deployments are the ones that want this.
#[derive(Clone, Default)]
pub struct CompiledTapeBackend {
    cache: Arc<RwLock<HashMap<String, Arc<lower::CompiledProgram>>>>,
    parallel: lower::ParallelCfg,
    /// Zone-map chunk counters, shared by every clone of this backend (one
    /// set per process, like the compile cache) — the server's `stats` op
    /// reports them.
    zone_counters: Arc<ZoneCounters>,
}

/// Process-wide chunk-skipping counters (see `lower::IndexedRun` for the
/// per-run form these accumulate).
#[derive(Default)]
struct ZoneCounters {
    chunks_skipped: AtomicU64,
    chunks_take_all: AtomicU64,
    chunks_scanned: AtomicU64,
}

impl ZoneCounters {
    fn absorb(&self, rep: &lower::IndexedRun) {
        let o = Ordering::Relaxed;
        self.chunks_skipped.fetch_add(rep.chunks_skipped, o);
        self.chunks_take_all.fetch_add(rep.chunks_take_all, o);
        self.chunks_scanned.fetch_add(rep.chunks_scanned, o);
    }

    fn snapshot(&self) -> lower::IndexedRun {
        let o = Ordering::Relaxed;
        lower::IndexedRun {
            chunks_skipped: self.chunks_skipped.load(o),
            chunks_take_all: self.chunks_take_all.load(o),
            chunks_scanned: self.chunks_scanned.load(o),
        }
    }
}

impl CompiledTapeBackend {
    pub fn new() -> CompiledTapeBackend {
        CompiledTapeBackend::default()
    }

    /// Set the intra-partition parallelism for every run through this
    /// backend (clones share the compile cache but keep their own config).
    pub fn with_parallelism(mut self, parallel: lower::ParallelCfg) -> CompiledTapeBackend {
        self.parallel = parallel;
        self
    }

    /// The configured intra-partition parallelism.
    pub fn parallelism(&self) -> lower::ParallelCfg {
        self.parallel
    }

    /// Run a query (kind- or source-based) over one partition.
    pub fn run(&self, query: &Query, cs: &ColumnSet, hist: &mut H1) -> Result<(), String> {
        self.run_indexed(query, cs, None, hist).map(|_| ())
    }

    /// `run` with a zone map: chunks the query's cut provably rejects are
    /// skipped, provably-accepted chunks run unmasked. Bit-identical to
    /// the unindexed run; the report also accumulates into the shared
    /// process-wide counters (`zone_stats`).
    pub fn run_indexed(
        &self,
        query: &Query,
        cs: &ColumnSet,
        zm: Option<&ZoneMap>,
        hist: &mut H1,
    ) -> Result<lower::IndexedRun, String> {
        match &query.source {
            Some(src) => self.run_source_indexed(src, cs, zm, hist),
            None => self.run_source_indexed(&source_for(query.kind, &query.list), cs, zm, hist),
        }
    }

    /// Run query-language source over one partition, compiling on first use.
    pub fn run_source(&self, src: &str, cs: &ColumnSet, hist: &mut H1) -> Result<(), String> {
        self.run_source_indexed(src, cs, None, hist).map(|_| ())
    }

    /// `run_source` with a zone map (see `run_indexed`).
    pub fn run_source_indexed(
        &self,
        src: &str,
        cs: &ColumnSet,
        zm: Option<&ZoneMap>,
        hist: &mut H1,
    ) -> Result<lower::IndexedRun, String> {
        let prog = self.program_for(src, cs)?;
        let rep = lower::run_parallel_indexed(&prog, cs, zm, hist, self.parallel)?;
        self.zone_counters.absorb(&rep);
        Ok(rep)
    }

    /// Shared-scan fusion: run several queries over one partition in a
    /// single streaming pass (`lower::run_fused_indexed`) so the columns
    /// stay hot in cache while every query's kernel consumes them.
    /// `hists[i]` receives query `i`'s result, bit-identical to what
    /// `run_indexed` would have produced for it alone; every per-query
    /// report also feeds the shared process-wide counters.
    pub fn run_fused_indexed(
        &self,
        queries: &[&Query],
        cs: &ColumnSet,
        zm: Option<&ZoneMap>,
        hists: &mut [H1],
    ) -> Result<Vec<lower::IndexedRun>, String> {
        let mut progs = Vec::with_capacity(queries.len());
        for q in queries {
            let src = match &q.source {
                Some(s) => s.clone(),
                None => source_for(q.kind, &q.list),
            };
            progs.push(self.program_for(&src, cs)?);
        }
        let refs: Vec<&lower::CompiledProgram> = progs.iter().map(|p| p.as_ref()).collect();
        let reps = lower::run_fused_indexed(&refs, cs, zm, hists, 0)?;
        for rep in &reps {
            self.zone_counters.absorb(rep);
        }
        Ok(reps)
    }

    /// `run_indexed` for the full statement set: builds the query's aux
    /// sinks (an H2 per `fill2`, a profile per `profile`, an H1 per
    /// `fill_vars` variation) from its binnings, fills them in the same
    /// pass as the primary and returns them. Aux-free programs return an
    /// empty vector, so callers can use this unconditionally.
    pub fn run_group_indexed(
        &self,
        query: &Query,
        cs: &ColumnSet,
        zm: Option<&ZoneMap>,
        hist: &mut H1,
    ) -> Result<(Vec<Sink>, lower::IndexedRun), String> {
        let src = match &query.source {
            Some(s) => s.clone(),
            None => source_for(query.kind, &query.list),
        };
        let prog = self.program_for(&src, cs)?;
        let (x, y) = query.binnings();
        let mut aux = prog.make_aux(x, y);
        let rep = lower::run_parallel_group_indexed(&prog, cs, zm, hist, &mut aux, self.parallel)?;
        self.zone_counters.absorb(&rep);
        Ok((aux, rep))
    }

    /// `run_fused_indexed` for the full statement set: every query's aux
    /// sinks fill directly from the shared scan and come back per query
    /// (empty vectors for aux-free programs).
    pub fn run_fused_group_indexed(
        &self,
        queries: &[&Query],
        cs: &ColumnSet,
        zm: Option<&ZoneMap>,
        hists: &mut [H1],
    ) -> Result<(Vec<Vec<Sink>>, Vec<lower::IndexedRun>), String> {
        let mut progs = Vec::with_capacity(queries.len());
        let mut auxes: Vec<Vec<Sink>> = Vec::with_capacity(queries.len());
        for q in queries {
            let src = match &q.source {
                Some(s) => s.clone(),
                None => source_for(q.kind, &q.list),
            };
            let prog = self.program_for(&src, cs)?;
            let (x, y) = q.binnings();
            auxes.push(prog.make_aux(x, y));
            progs.push(prog);
        }
        let refs: Vec<&lower::CompiledProgram> = progs.iter().map(|p| p.as_ref()).collect();
        let reps = lower::run_fused_group_indexed(&refs, cs, zm, hists, &mut auxes, 0)?;
        for rep in &reps {
            self.zone_counters.absorb(rep);
        }
        Ok((auxes, reps))
    }

    /// Chunk-skipping counters accumulated by every clone of this backend
    /// since process start.
    pub fn zone_stats(&self) -> lower::IndexedRun {
        self.zone_counters.snapshot()
    }

    /// Number of distinct programs compiled so far (observability/tests).
    pub fn compiled_count(&self) -> usize {
        self.cache.read().unwrap().len()
    }

    /// Which kernel a source query takes over this partition's schema:
    /// `Ok(Some(info))` when a chunked (mask-and-fill) batch kernel runs —
    /// `info.shape` says whether over item, event or pair lanes —
    /// `Ok(None)` when the closure-graph loop runs. Compiles — and
    /// caches — the program exactly as `run_source` would, so the report
    /// always matches what execution will do.
    pub fn kernel_info(
        &self,
        src: &str,
        cs: &ColumnSet,
    ) -> Result<Option<lower::ChunkedInfo>, String> {
        Ok(self.program_for(src, cs)?.chunked_info())
    }

    fn program_for(
        &self,
        src: &str,
        cs: &ColumnSet,
    ) -> Result<Arc<lower::CompiledProgram>, String> {
        // Key on source text + schema: the same text over a different
        // schema may transform to different column bindings.
        let key = format!("{src}\u{0}{}", cs.schema);
        if let Some(p) = self.cache.read().unwrap().get(&key) {
            return Ok(p.clone());
        }
        let flat = queryir::compile(src, &cs.schema)?;
        let compiled = Arc::new(lower::lower(&flat)?);
        self.cache
            .write()
            .unwrap()
            .insert(key, compiled.clone());
        Ok(compiled)
    }
}

impl std::fmt::Debug for CompiledTapeBackend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "CompiledTapeBackend({} programs, {} threads)",
            self.compiled_count(),
            self.parallel.resolved_threads()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::{generate_drellyan, generate_ttbar};
    use crate::engine::columnar_exec;

    fn assert_close(a: &H1, b: &H1, what: &str) {
        assert_eq!(a.total(), b.total(), "{what}: totals");
        let diff: f64 = a.bins.iter().zip(&b.bins).map(|(x, y)| (x - y).abs()).sum();
        assert!(diff <= 4.0, "{what}: bins differ by {diff}");
    }

    #[test]
    fn kinds_match_handwritten_columnar_on_muons() {
        let cs = generate_drellyan(2000, 41);
        let be = CompiledTapeBackend::new();
        for kind in QueryKind::ALL {
            let q = Query::new(kind, "dy", "muons");
            let mut h_hand = H1::new(q.n_bins, q.lo, q.hi);
            columnar_exec::run(kind, &cs, "muons", &mut h_hand).unwrap();
            let mut h_comp = H1::new(q.n_bins, q.lo, q.hi);
            be.run(&q, &cs, &mut h_comp).unwrap();
            assert_close(&h_comp, &h_hand, kind.artifact());
        }
        // One program per kind, compiled once.
        assert_eq!(be.compiled_count(), QueryKind::ALL.len());
        // Re-running does not recompile.
        let q = Query::new(QueryKind::MaxPt, "dy", "muons");
        let mut h = H1::new(q.n_bins, q.lo, q.hi);
        be.run(&q, &cs, &mut h).unwrap();
        assert_eq!(be.compiled_count(), QueryKind::ALL.len());
    }

    #[test]
    fn works_over_other_lists() {
        // The same built-in kinds run over the jets list of a tt̄ sample —
        // the thing the hand-written backend needed new Rust code for.
        let cs = generate_ttbar(500, 6, 42);
        let be = CompiledTapeBackend::new();
        let q = Query::new(QueryKind::MaxPt, "tt", "jets");
        let mut h_hand = H1::new(q.n_bins, q.lo, q.hi);
        columnar_exec::run(QueryKind::MaxPt, &cs, "jets", &mut h_hand).unwrap();
        let mut h_comp = H1::new(q.n_bins, q.lo, q.hi);
        be.run(&q, &cs, &mut h_comp).unwrap();
        assert_close(&h_comp, &h_hand, "jets max_pt");
    }

    #[test]
    fn parallel_backend_matches_sequential_backend() {
        let cs = generate_drellyan(6_000, 44);
        let seq = CompiledTapeBackend::new();
        let par = CompiledTapeBackend::new().with_parallelism(lower::ParallelCfg {
            threads: 4,
            morsel_events: 512,
        });
        for kind in QueryKind::ALL {
            let q = Query::new(kind, "dy", "muons");
            let mut h_seq = H1::new(q.n_bins, q.lo, q.hi);
            seq.run(&q, &cs, &mut h_seq).unwrap();
            let mut h_par = H1::new(q.n_bins, q.lo, q.hi);
            par.run(&q, &cs, &mut h_par).unwrap();
            assert_eq!(h_seq.bins, h_par.bins, "{}", kind.artifact());
            assert_eq!(h_seq.count, h_par.count, "{}", kind.artifact());
        }
    }

    /// Cut-based and multi-Fill source queries — the shapes real physics
    /// selections use — reach the chunked batch kernel through the backend,
    /// and the lowering report says so.
    #[test]
    fn cut_and_multi_fill_queries_reach_the_chunked_kernel() {
        let cs = generate_drellyan(3_000, 45);
        let be = CompiledTapeBackend::new().with_parallelism(lower::ParallelCfg {
            threads: 2,
            morsel_events: 512,
        });
        let src = "\
for event in dataset:
    for muon in event.muons:
        if muon.pt > 20:
            fill(muon.pt)
        fill(muon.eta, 0.5)
";
        let info = be.kernel_info(src, &cs).unwrap().expect("should lower chunked");
        assert_eq!(info.fills, 2);
        assert_eq!(info.masked_fills, 1);
        // The parallel (morsel) run of the masked kernel matches a fresh
        // sequential backend bin-for-bin.
        let mut par = H1::new(64, -4.0, 128.0);
        be.run_source(src, &cs, &mut par).unwrap();
        let mut seq = H1::new(64, -4.0, 128.0);
        CompiledTapeBackend::new().run_source(src, &cs, &mut seq).unwrap();
        assert_eq!(seq.bins, par.bins);
        assert_eq!(seq.count, par.count);
        assert!(seq.total() > 0.0);
    }

    /// Fused multi-query execution through the backend is bit-identical to
    /// running each query alone — histograms *and* moments.
    #[test]
    fn fused_backend_run_matches_solo_runs() {
        let cs = generate_drellyan(4_000, 46);
        let be = CompiledTapeBackend::new();
        let queries = [
            Query::new(QueryKind::FlatHist, "dy", "muons"),
            Query::new(QueryKind::MassPairs, "dy", "muons"),
            Query::new(QueryKind::MaxPt, "dy", "muons"),
        ];
        let refs: Vec<&Query> = queries.iter().collect();
        let mut fused: Vec<H1> = queries
            .iter()
            .map(|q| H1::new(q.n_bins, q.lo, q.hi))
            .collect();
        let reps = be.run_fused_indexed(&refs, &cs, None, &mut fused).unwrap();
        assert_eq!(reps.len(), queries.len());
        for (q, h) in queries.iter().zip(&fused) {
            let mut solo = H1::new(q.n_bins, q.lo, q.hi);
            CompiledTapeBackend::new().run(q, &cs, &mut solo).unwrap();
            assert_eq!(*h, solo, "{}", q.kind.artifact());
        }
    }

    /// AGC-style statement set through the backend group APIs: aux sinks
    /// come back filled, bit-identically from the solo and fused paths,
    /// while the H1-only paths refuse the program.
    #[test]
    fn group_apis_return_filled_aux_sinks() {
        let cs = generate_drellyan(3_000, 47);
        let be = CompiledTapeBackend::new();
        let src = "\
for event in dataset:
    for muon in event.muons:
        fill(muon.pt)
        fill2(muon.pt, muon.eta)
        fill_vars(muon.pt, 0.5, 1.0, 2.0)
";
        let q = Query::from_source(src, "dy").with_y_binning(16, -4.0, 4.0);
        let mut h = H1::new(q.n_bins, q.lo, q.hi);
        let (aux, _rep) = be.run_group_indexed(&q, &cs, None, &mut h).unwrap();
        assert_eq!(aux.len(), 4); // h2 + 3 weight variations
        assert!(aux.iter().all(|s| s.hist.total() > 0.0));
        // The H1-only path refuses rather than dropping aux fills.
        let mut h1 = H1::new(q.n_bins, q.lo, q.hi);
        assert!(be.run_indexed(&q, &cs, None, &mut h1).is_err());
        // The fused group path matches the solo group run bit-for-bit.
        let plain = Query::new(QueryKind::FlatHist, "dy", "muons");
        let refs = [&q, &plain];
        let mut hists = vec![
            H1::new(q.n_bins, q.lo, q.hi),
            H1::new(plain.n_bins, plain.lo, plain.hi),
        ];
        let (auxes, reps) = be
            .run_fused_group_indexed(&refs, &cs, None, &mut hists)
            .unwrap();
        assert_eq!(reps.len(), 2);
        assert_eq!(hists[0], h);
        assert_eq!(auxes[0], aux);
        assert!(auxes[1].is_empty());
    }

    #[test]
    fn source_queries_run_and_cache() {
        let cs = generate_drellyan(800, 43);
        let be = CompiledTapeBackend::new();
        let src = "for event in dataset:\n    for m in event.muons:\n        fill(m.pt)\n";
        let mut h = H1::new(64, 0.0, 128.0);
        be.run_source(src, &cs, &mut h).unwrap();
        assert!(h.total() > 0.0);
        assert_eq!(be.compiled_count(), 1);
        // Bad source surfaces a compile error, not a worker crash.
        let err = be
            .run_source("for event in dataset:\n    fill(nope)\n", &cs, &mut h)
            .unwrap_err();
        assert!(err.contains("nope"), "{err}");
    }
}
