//! Query description: what a physicist asks for in one exploratory step —
//! one analysis function over one dataset, yielding one histogram.

use crate::util::json::Json;

/// The four Table-3 analysis functions plus the Table-1 flat fill.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum QueryKind {
    /// Per-event max muon pt.
    MaxPt,
    /// Eta of the highest-pt muon per event.
    EtaBest,
    /// pt_i + pt_j over distinct pairs.
    PtSumPairs,
    /// Dimuon invariant mass over distinct pairs.
    MassPairs,
    /// Histogram every item of one branch (Table 1's jet-pt fill).
    FlatHist,
}

impl QueryKind {
    pub const ALL: [QueryKind; 5] = [
        QueryKind::MaxPt,
        QueryKind::EtaBest,
        QueryKind::PtSumPairs,
        QueryKind::MassPairs,
        QueryKind::FlatHist,
    ];

    /// Artifact name in the manifest.
    pub fn artifact(&self) -> &'static str {
        match self {
            QueryKind::MaxPt => "max_pt",
            QueryKind::EtaBest => "eta_best",
            QueryKind::PtSumPairs => "ptsum_pairs",
            QueryKind::MassPairs => "mass_pairs",
            QueryKind::FlatHist => "flat_hist",
        }
    }

    pub fn from_name(s: &str) -> Option<QueryKind> {
        Some(match s {
            "max_pt" => QueryKind::MaxPt,
            "eta_best" => QueryKind::EtaBest,
            "ptsum_pairs" => QueryKind::PtSumPairs,
            "mass_pairs" => QueryKind::MassPairs,
            "flat_hist" => QueryKind::FlatHist,
            _ => return None,
        })
    }

    /// Leaf attribute names (relative to the list) the query touches —
    /// selective reading loads exactly these.
    pub fn attrs(&self) -> &'static [&'static str] {
        match self {
            QueryKind::MaxPt | QueryKind::PtSumPairs | QueryKind::FlatHist => &["pt"],
            QueryKind::EtaBest => &["pt", "eta"],
            QueryKind::MassPairs => &["pt", "eta", "phi"],
        }
    }

    /// Full leaf paths under a list prefix (e.g. "muons" → "muons.pt"...).
    pub fn leaf_paths(&self, list: &str) -> Vec<String> {
        self.attrs().iter().map(|a| format!("{list}.{a}")).collect()
    }

    /// A sensible default binning for each function.
    pub fn default_binning(&self) -> (f64, f64) {
        match self {
            QueryKind::MaxPt => (0.0, 128.0),
            QueryKind::EtaBest => (-2.4, 2.4),
            QueryKind::PtSumPairs => (0.0, 256.0),
            QueryKind::MassPairs => (0.0, 128.0),
            QueryKind::FlatHist => (0.0, 256.0),
        }
    }
}

/// A full query: function + dataset + binning.
///
/// Queries come in two forms: a built-in `kind` (the Table-3 functions,
/// which every backend knows), or free-form query-language `source`
/// (executed by the code-transformation backends — `Backend::CompiledTape`
/// compiles it, `Backend::Columnar` interprets the transformed tape). When
/// `source` is set, `kind` is a placeholder and is ignored by execution.
#[derive(Clone, Debug, PartialEq)]
pub struct Query {
    pub kind: QueryKind,
    /// Query-language source text; overrides `kind` when present.
    pub source: Option<String>,
    /// Dataset name (resolved by the coordinator's catalog).
    pub dataset: String,
    /// List path the function iterates over ("muons", "jets").
    pub list: String,
    pub n_bins: usize,
    pub lo: f64,
    pub hi: f64,
    /// Y binning for `fill2` H2 sinks (harmless for queries without one).
    pub y_bins: usize,
    pub y_lo: f64,
    pub y_hi: f64,
    /// Accept a degraded answer: when some partitions have no readable
    /// replica, return the merged histogram over the healthy ones plus a
    /// per-partition error manifest instead of failing the whole query.
    pub allow_partial: bool,
}

impl Query {
    pub fn new(kind: QueryKind, dataset: &str, list: &str) -> Query {
        let (lo, hi) = kind.default_binning();
        Query {
            kind,
            source: None,
            dataset: dataset.to_string(),
            list: list.to_string(),
            n_bins: 64,
            lo,
            hi,
            y_bins: 32,
            y_lo: 0.0,
            y_hi: 128.0,
            allow_partial: false,
        }
    }

    /// A free-form query-language query (the exploratory-physics path).
    pub fn from_source(src: impl Into<String>, dataset: &str) -> Query {
        Query {
            kind: QueryKind::FlatHist,
            source: Some(src.into()),
            dataset: dataset.to_string(),
            list: String::new(),
            n_bins: 64,
            lo: 0.0,
            hi: 128.0,
            y_bins: 32,
            y_lo: 0.0,
            y_hi: 128.0,
            allow_partial: false,
        }
    }

    pub fn with_binning(mut self, n_bins: usize, lo: f64, hi: f64) -> Query {
        self.n_bins = n_bins;
        self.lo = lo;
        self.hi = hi;
        self
    }

    /// Y binning for the H2 sinks of `fill2` sites.
    pub fn with_y_binning(mut self, y_bins: usize, y_lo: f64, y_hi: f64) -> Query {
        self.y_bins = y_bins;
        self.y_lo = y_lo;
        self.y_hi = y_hi;
        self
    }

    /// Tolerate unreadable partitions, returning a partial result.
    pub fn with_allow_partial(mut self, yes: bool) -> Query {
        self.allow_partial = yes;
        self
    }

    /// The two binning tuples `make_aux` takes.
    pub fn binnings(&self) -> ((usize, f64, f64), (usize, f64, f64)) {
        (
            (self.n_bins, self.lo, self.hi),
            (self.y_bins, self.y_lo, self.y_hi),
        )
    }

    pub fn leaf_paths(&self) -> Vec<String> {
        self.kind.leaf_paths(&self.list)
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("kind", Json::str(self.kind.artifact())),
            ("dataset", Json::str(self.dataset.clone())),
            ("list", Json::str(self.list.clone())),
            ("n_bins", Json::num(self.n_bins as f64)),
            ("lo", Json::num(self.lo)),
            ("hi", Json::num(self.hi)),
        ];
        if let Some(src) = &self.source {
            pairs.push(("src", Json::str(src.clone())));
        }
        // Only serialized when non-default, so classic requests (and their
        // cache keys / goldens) are byte-identical to earlier versions.
        if (self.y_bins, self.y_lo, self.y_hi) != (32, 0.0, 128.0) {
            pairs.push(("y_bins", Json::num(self.y_bins as f64)));
            pairs.push(("y_lo", Json::num(self.y_lo)));
            pairs.push(("y_hi", Json::num(self.y_hi)));
        }
        if self.allow_partial {
            pairs.push(("allow_partial", Json::Bool(true)));
        }
        Json::obj(pairs)
    }

    pub fn from_json(j: &Json) -> Result<Query, String> {
        let source = j.get("src").and_then(|v| v.as_str()).map(|s| s.to_string());
        let kind = match j.get("kind").and_then(|v| v.as_str()) {
            Some(name) => QueryKind::from_name(name).ok_or("unknown kind")?,
            // Source queries need no kind; keep a harmless placeholder.
            None if source.is_some() => QueryKind::FlatHist,
            None => return Err("missing kind".to_string()),
        };
        Ok(Query {
            kind,
            source,
            dataset: j
                .get("dataset")
                .and_then(|v| v.as_str())
                .ok_or("missing dataset")?
                .to_string(),
            list: j.get("list").and_then(|v| v.as_str()).unwrap_or("muons").to_string(),
            n_bins: j.get("n_bins").and_then(|v| v.as_usize()).unwrap_or(64),
            lo: j.get("lo").and_then(|v| v.as_f64()).unwrap_or(0.0),
            hi: j.get("hi").and_then(|v| v.as_f64()).unwrap_or(128.0),
            y_bins: j.get("y_bins").and_then(|v| v.as_usize()).unwrap_or(32),
            y_lo: j.get("y_lo").and_then(|v| v.as_f64()).unwrap_or(0.0),
            y_hi: j.get("y_hi").and_then(|v| v.as_f64()).unwrap_or(128.0),
            allow_partial: j.get("allow_partial").and_then(|v| v.as_bool()).unwrap_or(false),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_names_roundtrip() {
        for k in QueryKind::ALL {
            assert_eq!(QueryKind::from_name(k.artifact()), Some(k));
        }
        assert_eq!(QueryKind::from_name("bogus"), None);
    }

    #[test]
    fn leaf_paths_selective() {
        assert_eq!(QueryKind::MassPairs.leaf_paths("muons"),
                   vec!["muons.pt", "muons.eta", "muons.phi"]);
        assert_eq!(QueryKind::MaxPt.leaf_paths("jets"), vec!["jets.pt"]);
    }

    #[test]
    fn json_roundtrip() {
        let q = Query::new(QueryKind::MassPairs, "dy", "muons").with_binning(64, 0.0, 128.0);
        let j = Json::parse(&q.to_json().to_string()).unwrap();
        assert_eq!(Query::from_json(&j).unwrap(), q);
    }

    #[test]
    fn y_binning_roundtrips_and_defaults_stay_compact() {
        let q = Query::from_source("for event in dataset:\n    fill(event.met)\n", "dy")
            .with_y_binning(16, -4.0, 4.0);
        let j = Json::parse(&q.to_json().to_string()).unwrap();
        assert_eq!(Query::from_json(&j).unwrap(), q);
        // Default y binning stays off the wire (stable cache keys).
        let d = Query::new(QueryKind::MaxPt, "dy", "muons");
        assert!(d.to_json().get("y_bins").is_none());
        let j = Json::parse(&d.to_json().to_string()).unwrap();
        assert_eq!(Query::from_json(&j).unwrap(), d);
    }

    #[test]
    fn allow_partial_roundtrips_and_default_stays_compact() {
        let d = Query::new(QueryKind::MaxPt, "dy", "muons");
        // Off the wire by default: cache keys for classic queries unchanged.
        assert!(d.to_json().get("allow_partial").is_none());
        let q = d.clone().with_allow_partial(true);
        let j = Json::parse(&q.to_json().to_string()).unwrap();
        let back = Query::from_json(&j).unwrap();
        assert!(back.allow_partial);
        assert_eq!(back, q);
    }

    #[test]
    fn source_query_json_roundtrip() {
        let src = "for event in dataset:\n    fill(event.met)\n";
        let q = Query::from_source(src, "dy").with_binning(32, 0.0, 100.0);
        let j = Json::parse(&q.to_json().to_string()).unwrap();
        let back = Query::from_json(&j).unwrap();
        assert_eq!(back.source.as_deref(), Some(src));
        assert_eq!(back, q);
        // A src-only request (no kind) parses too.
        let req = Json::parse(
            r#"{"op":"query","src":"for event in dataset:\n    fill(event.met)\n","dataset":"dy"}"#,
        )
        .unwrap();
        let q2 = Query::from_json(&req).unwrap();
        assert!(q2.source.is_some());
    }
}
