//! PJRT runtime: load AOT artifacts (HLO text) and execute them on the hot
//! path. Python is never involved here — `artifacts/` is the only interface
//! between the build-time compile chain and the serving coordinator.

pub mod artifact;
pub mod exec;

pub use artifact::{ArtifactRegistry, Manifest, PartitionShape};
pub use exec::{PaddedPartition, QueryExecutable};
