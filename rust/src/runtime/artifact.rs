//! Artifact registry: parse `artifacts/manifest.json`, load HLO-text
//! modules, compile them once on the PJRT CPU client and cache the
//! executables for the lifetime of the process.
//!
//! Compilation happens at startup (or first use), never per-query: the
//! paper's latency budget (a second per plot) cannot absorb an XLA compile.

use crate::util::json::Json;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

/// Partition shapes baked into the artifacts (must match what the Rust side
/// pads to — see `engine::padded`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PartitionShape {
    pub n_events: usize,
    pub k_max: usize,
    pub content_cap: usize,
    pub n_offsets: usize,
    pub nbins: usize,
    pub hist_slots: usize,
}

#[derive(Clone, Debug)]
pub struct QueryArtifact {
    pub name: String,
    pub file: PathBuf,
    pub n_content_arrays: usize,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub shape: PartitionShape,
    pub queries: Vec<QueryArtifact>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest, String> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .map_err(|e| format!("read manifest: {e} (run `make artifacts`)"))?;
        let j = Json::parse(&text).map_err(|e| format!("manifest: {e}"))?;
        let part = j.get("partition").ok_or("manifest: missing partition")?;
        let shape = PartitionShape {
            n_events: part.get("n_events").and_then(|v| v.as_usize()).ok_or("n_events")?,
            k_max: part.get("k_max").and_then(|v| v.as_usize()).ok_or("k_max")?,
            content_cap: part
                .get("content_cap")
                .and_then(|v| v.as_usize())
                .ok_or("content_cap")?,
            n_offsets: part.get("n_offsets").and_then(|v| v.as_usize()).ok_or("n_offsets")?,
            nbins: j.get("nbins").and_then(|v| v.as_usize()).ok_or("nbins")?,
            hist_slots: j.get("hist_slots").and_then(|v| v.as_usize()).ok_or("hist_slots")?,
        };
        let mut queries = Vec::new();
        for (name, q) in j.get("queries").and_then(|v| v.as_obj()).ok_or("queries")? {
            queries.push(QueryArtifact {
                name: name.clone(),
                file: dir.join(q.get("file").and_then(|v| v.as_str()).ok_or("file")?),
                n_content_arrays: q
                    .get("n_content_arrays")
                    .and_then(|v| v.as_usize())
                    .ok_or("n_content_arrays")?,
            });
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            shape,
            queries,
        })
    }

    pub fn query(&self, name: &str) -> Option<&QueryArtifact> {
        self.queries.iter().find(|q| q.name == name)
    }
}

/// Compiled-executable cache. One PJRT client per registry; executables are
/// compiled on demand and shared behind `Arc`.
pub struct ArtifactRegistry {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    compiled: Mutex<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
}

impl ArtifactRegistry {
    pub fn open(dir: &Path) -> Result<ArtifactRegistry, String> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| format!("pjrt cpu client: {e:?}"))?;
        crate::log_info!(
            "pjrt client up: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(ArtifactRegistry {
            manifest,
            client,
            compiled: Mutex::new(HashMap::new()),
        })
    }

    pub fn shape(&self) -> PartitionShape {
        self.manifest.shape
    }

    /// Get (compiling if needed) the executable for a query name.
    pub fn executable(&self, name: &str) -> Result<Arc<xla::PjRtLoadedExecutable>, String> {
        if let Some(e) = self.compiled.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let art = self
            .manifest
            .query(name)
            .ok_or_else(|| format!("no artifact for query '{name}'"))?
            .clone();
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            art.file.to_str().ok_or("bad path")?,
        )
        .map_err(|e| format!("parse {}: {e:?}", art.file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| format!("compile {name}: {e:?}"))?;
        crate::log_info!("compiled artifact '{name}' in {:.2}s", t0.elapsed().as_secs_f64());
        let exe = Arc::new(exe);
        self.compiled
            .lock()
            .unwrap()
            .insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Eagerly compile every artifact (server startup).
    pub fn warm_all(&self) -> Result<(), String> {
        let names: Vec<String> = self.manifest.queries.iter().map(|q| q.name.clone()).collect();
        for name in names {
            self.executable(&name)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parse_roundtrip() {
        let dir = std::env::temp_dir().join("hepq-manifest-test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"version":1,"nbins":64,"hist_slots":66,
                "partition":{"n_events":16384,"k_max":8,"content_cap":131072,"n_offsets":16385},
                "queries":{"max_pt":{"file":"q_max_pt.hlo.txt","n_content_arrays":1,
                           "inputs":["offsets_i32","content_f32_0","lo_f32","hi_f32"],
                           "output":"hist_f32_slots"}}}"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.shape.n_events, 16384);
        assert_eq!(m.shape.hist_slots, 66);
        assert_eq!(m.query("max_pt").unwrap().n_content_arrays, 1);
        assert!(m.query("nope").is_none());
    }

    #[test]
    fn missing_manifest_is_helpful() {
        let err = Manifest::load(Path::new("/definitely/not/here")).unwrap_err();
        assert!(err.contains("make artifacts"));
    }
}
