//! Partition execution: pack padded partitions into PJRT literals, run the
//! compiled query, unpack the [underflow, bins..., overflow] histogram.

use super::artifact::{ArtifactRegistry, PartitionShape};
use crate::columnar::arrays::ColumnSet;
use crate::hist::H1;
use std::sync::Arc;

/// A partition padded to the artifact's static wire layout:
/// offsets i32[N+1] (monotone, padding events empty) and each content array
/// f32[C] (zero-padded).
#[derive(Clone, Debug)]
pub struct PaddedPartition {
    pub offsets: Vec<i32>,
    pub contents: Vec<Vec<f32>>,
    /// Real (unpadded) event count, for accounting.
    pub n_live_events: usize,
}

impl PaddedPartition {
    /// Pad an exploded partition for a query over the given leaf paths.
    /// `list_path` is the list whose offsets drive the query (e.g. "muons").
    pub fn from_columns(
        cs: &ColumnSet,
        list_path: &str,
        leaf_paths: &[&str],
        shape: PartitionShape,
    ) -> Result<PaddedPartition, String> {
        if cs.n_events > shape.n_events {
            return Err(format!(
                "partition has {} events, artifact takes at most {}",
                cs.n_events, shape.n_events
            ));
        }
        let off64 = cs
            .offsets_of(list_path)
            .ok_or_else(|| format!("no list '{list_path}'"))?;
        let total = *off64.last().unwrap_or(&0) as usize;
        if total > shape.content_cap {
            return Err(format!(
                "partition has {total} items, content capacity is {}",
                shape.content_cap
            ));
        }
        let mut offsets = Vec::with_capacity(shape.n_offsets);
        offsets.extend(off64.iter().map(|&o| o as i32));
        let last = *offsets.last().unwrap_or(&0);
        offsets.resize(shape.n_offsets, last); // padding events are empty

        let mut contents = Vec::with_capacity(leaf_paths.len());
        for path in leaf_paths {
            let arr = cs
                .leaf(path)
                .ok_or_else(|| format!("no leaf '{path}'"))?
                .as_f32()
                .ok_or_else(|| format!("leaf '{path}' is not f32"))?;
            let mut v = Vec::with_capacity(shape.content_cap);
            v.extend_from_slice(arr);
            v.resize(shape.content_cap, 0.0);
            contents.push(v);
        }
        Ok(PaddedPartition {
            offsets,
            contents,
            n_live_events: cs.n_events,
        })
    }
}

/// A query bound to its compiled executable — the per-partition hot path.
pub struct QueryExecutable {
    pub name: String,
    shape: PartitionShape,
    exe: Arc<xla::PjRtLoadedExecutable>,
    n_content_arrays: usize,
}

impl QueryExecutable {
    pub fn new(reg: &ArtifactRegistry, name: &str) -> Result<QueryExecutable, String> {
        let art = reg
            .manifest
            .query(name)
            .ok_or_else(|| format!("unknown query '{name}'"))?;
        Ok(QueryExecutable {
            name: name.to_string(),
            shape: reg.shape(),
            exe: reg.executable(name)?,
            n_content_arrays: art.n_content_arrays,
        })
    }

    pub fn shape(&self) -> PartitionShape {
        self.shape
    }

    /// Execute over one padded partition, adding into `hist`.
    pub fn run(
        &self,
        part: &PaddedPartition,
        lo: f64,
        hi: f64,
        hist: &mut H1,
    ) -> Result<(), String> {
        let slots = self.run_raw(part, lo, hi)?;
        let nbins = self.shape.nbins;
        hist.add_bins(&slots[1..=nbins], slots[0] as f64, slots[nbins + 1] as f64)
    }

    /// Execute and return the raw [underflow, bins..., overflow] slots.
    pub fn run_raw(&self, part: &PaddedPartition, lo: f64, hi: f64) -> Result<Vec<f32>, String> {
        if part.contents.len() != self.n_content_arrays {
            return Err(format!(
                "query '{}' takes {} content arrays, got {}",
                self.name,
                self.n_content_arrays,
                part.contents.len()
            ));
        }
        if part.offsets.len() != self.shape.n_offsets {
            return Err(format!(
                "offsets length {} != {}",
                part.offsets.len(),
                self.shape.n_offsets
            ));
        }
        let mut literals: Vec<xla::Literal> = Vec::with_capacity(2 + part.contents.len());
        literals.push(xla::Literal::vec1(&part.offsets));
        for c in &part.contents {
            if c.len() != self.shape.content_cap {
                return Err(format!(
                    "content length {} != {}",
                    c.len(),
                    self.shape.content_cap
                ));
            }
            literals.push(xla::Literal::vec1(c.as_slice()));
        }
        literals.push(xla::Literal::vec1(&[lo as f32]));
        literals.push(xla::Literal::vec1(&[hi as f32]));

        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| format!("execute '{}': {e:?}", self.name))?[0][0]
            .to_literal_sync()
            .map_err(|e| format!("to_literal: {e:?}"))?;
        let out = result.to_tuple1().map_err(|e| format!("tuple: {e:?}"))?;
        let slots = out.to_vec::<f32>().map_err(|e| format!("to_vec: {e:?}"))?;
        if slots.len() != self.shape.hist_slots {
            return Err(format!(
                "kernel returned {} slots, expected {}",
                slots.len(),
                self.shape.hist_slots
            ));
        }
        Ok(slots)
    }
}
