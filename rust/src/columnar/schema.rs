//! Dataset schema: the logical, object-oriented type of an event, which the
//! columnar layer "explodes" (ROOT: "splits") into flat arrays.
//!
//! A schema is a tree of primitives, variable-length lists, and records.
//! Every *leaf* primitive corresponds to one content array (a "branch"), and
//! every *list* node corresponds to one offsets array — exactly the encoding
//! of Table 2 in the paper.

use crate::util::json::Json;
use std::fmt;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PrimType {
    F32,
    F64,
    I32,
    I64,
    Bool,
}

impl PrimType {
    pub fn name(&self) -> &'static str {
        match self {
            PrimType::F32 => "f32",
            PrimType::F64 => "f64",
            PrimType::I32 => "i32",
            PrimType::I64 => "i64",
            PrimType::Bool => "bool",
        }
    }

    pub fn from_name(s: &str) -> Option<PrimType> {
        Some(match s {
            "f32" => PrimType::F32,
            "f64" => PrimType::F64,
            "i32" => PrimType::I32,
            "i64" => PrimType::I64,
            "bool" => PrimType::Bool,
            _ => return None,
        })
    }

    pub fn byte_width(&self) -> usize {
        match self {
            PrimType::F32 | PrimType::I32 => 4,
            PrimType::F64 | PrimType::I64 => 8,
            PrimType::Bool => 1,
        }
    }
}

#[derive(Clone, Debug, PartialEq)]
pub struct Field {
    pub name: String,
    pub ty: Ty,
}

#[derive(Clone, Debug, PartialEq)]
pub enum Ty {
    Prim(PrimType),
    List(Box<Ty>),
    Record(Vec<Field>),
}

impl Ty {
    pub fn record(fields: Vec<(&str, Ty)>) -> Ty {
        Ty::Record(
            fields
                .into_iter()
                .map(|(n, t)| Field {
                    name: n.to_string(),
                    ty: t,
                })
                .collect(),
        )
    }

    pub fn list(inner: Ty) -> Ty {
        Ty::List(Box::new(inner))
    }

    pub fn field(&self, name: &str) -> Option<&Ty> {
        match self {
            Ty::Record(fs) => fs.iter().find(|f| f.name == name).map(|f| &f.ty),
            _ => None,
        }
    }

    /// Resolve a dotted path (records only; lists are transparent —
    /// `muons.pt` names the pt leaf *under* the muons list).
    pub fn resolve(&self, dotted: &str) -> Option<&Ty> {
        let mut cur = self.skip_lists();
        for part in dotted.split('.') {
            cur = cur.field(part)?.skip_lists_shallow();
        }
        Some(cur)
    }

    fn skip_lists(&self) -> &Ty {
        match self {
            Ty::List(inner) => inner.skip_lists(),
            t => t,
        }
    }

    fn skip_lists_shallow(&self) -> &Ty {
        // For path resolution we look *through* a single list layer so that
        // "muons.pt" works, but keep the leaf type itself.
        match self {
            Ty::List(inner) => inner.skip_lists(),
            t => t,
        }
    }

    /// Enumerate (leaf_path, PrimType) for all content arrays, and
    /// (list_path,) for all offsets arrays, in schema order. Nested lists at
    /// the same record path get `[]` suffixes per extra depth, so every
    /// offsets array has a unique key (`hits`, `hits[]`, ...).
    pub fn layout(&self) -> Layout {
        let mut layout = Layout::default();
        walk(self, String::new(), 0, &mut layout);
        layout
    }

    pub fn to_json(&self) -> Json {
        match self {
            Ty::Prim(p) => Json::str(p.name()),
            Ty::List(inner) => Json::obj(vec![("list", inner.to_json())]),
            Ty::Record(fields) => Json::obj(vec![(
                "record",
                Json::Arr(
                    fields
                        .iter()
                        .map(|f| {
                            Json::obj(vec![
                                ("name", Json::str(f.name.clone())),
                                ("type", f.ty.to_json()),
                            ])
                        })
                        .collect(),
                ),
            )]),
        }
    }

    pub fn from_json(j: &Json) -> Result<Ty, String> {
        match j {
            Json::Str(s) => PrimType::from_name(s)
                .map(Ty::Prim)
                .ok_or_else(|| format!("unknown primitive '{s}'")),
            Json::Obj(_) => {
                if let Some(inner) = j.get("list") {
                    Ok(Ty::List(Box::new(Ty::from_json(inner)?)))
                } else if let Some(fields) = j.get("record") {
                    let arr = fields.as_arr().ok_or("record must be an array")?;
                    let mut fs = Vec::with_capacity(arr.len());
                    for f in arr {
                        let name = f
                            .get("name")
                            .and_then(|n| n.as_str())
                            .ok_or("field needs a name")?
                            .to_string();
                        let ty = Ty::from_json(f.get("type").ok_or("field needs a type")?)?;
                        fs.push(Field { name, ty });
                    }
                    Ok(Ty::Record(fs))
                } else {
                    Err("object must have 'list' or 'record'".into())
                }
            }
            _ => Err("bad schema json".into()),
        }
    }
}

impl fmt::Display for Ty {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Ty::Prim(p) => write!(f, "{}", p.name()),
            Ty::List(inner) => write!(f, "[{inner}]"),
            Ty::Record(fields) => {
                write!(f, "{{")?;
                for (i, fd) in fields.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{}: {}", fd.name, fd.ty)?;
                }
                write!(f, "}}")
            }
        }
    }
}

/// The physical layout implied by a schema.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Layout {
    /// Paths of offsets arrays, outermost first (e.g. `["muons"]`, or for
    /// list-of-list `["hits", "hits.samples"]`).
    pub lists: Vec<String>,
    /// (path, prim) of every content array, e.g. `("muons.pt", F32)`.
    pub leaves: Vec<(String, PrimType)>,
}

fn walk(ty: &Ty, prefix: String, list_depth: usize, out: &mut Layout) {
    match ty {
        Ty::Prim(p) => out.leaves.push((prefix, *p)),
        Ty::List(inner) => {
            let key = if list_depth == 0 {
                prefix.clone()
            } else {
                format!("{prefix}{}", "[]".repeat(list_depth))
            };
            out.lists.push(key);
            walk(inner, prefix, list_depth + 1, out);
        }
        Ty::Record(fields) => {
            for f in fields {
                let child = if prefix.is_empty() {
                    f.name.clone()
                } else {
                    format!("{prefix}.{}", f.name)
                };
                walk(&f.ty, child, 0, out);
            }
        }
    }
}

/// The standard muon-event schema used across examples/tests: a Drell-Yan
/// style event with a variable-length list of muons and event-level MET.
pub fn muon_event_schema() -> Ty {
    Ty::record(vec![
        (
            "muons",
            Ty::list(Ty::record(vec![
                ("pt", Ty::Prim(PrimType::F32)),
                ("eta", Ty::Prim(PrimType::F32)),
                ("phi", Ty::Prim(PrimType::F32)),
                ("charge", Ty::Prim(PrimType::I32)),
            ])),
        ),
        ("met", Ty::Prim(PrimType::F32)),
    ])
}

/// Jet-rich schema for the Table-1 experiment: `n_attrs` attributes per jet
/// (the paper's tt̄ sample has 95 jet branches).
pub fn jet_event_schema(n_attrs: usize) -> Ty {
    let mut fields: Vec<(String, Ty)> = vec![
        ("pt".to_string(), Ty::Prim(PrimType::F32)),
        ("eta".to_string(), Ty::Prim(PrimType::F32)),
        ("phi".to_string(), Ty::Prim(PrimType::F32)),
        ("mass".to_string(), Ty::Prim(PrimType::F32)),
    ];
    for i in fields.len()..n_attrs {
        fields.push((format!("attr{i:02}"), Ty::Prim(PrimType::F32)));
    }
    Ty::Record(vec![Field {
        name: "jets".to_string(),
        ty: Ty::List(Box::new(Ty::Record(
            fields
                .into_iter()
                .map(|(name, ty)| Field { name, ty })
                .collect(),
        ))),
    }])
}

/// The AGC-style tt̄ event schema: Table 1's jet list (`n_attrs` branches)
/// plus a small muon list, so cross-list queries (muon × jet pairs,
/// lepton-indexed gathers) have two real collections to range over.
pub fn ttbar_event_schema(n_attrs: usize) -> Ty {
    let Ty::Record(mut fields) = jet_event_schema(n_attrs) else {
        unreachable!("jet_event_schema returns a record")
    };
    fields.push(Field {
        name: "muons".to_string(),
        ty: Ty::List(Box::new(Ty::Record(
            ["pt", "eta", "phi"]
                .iter()
                .map(|name| Field { name: name.to_string(), ty: Ty::Prim(PrimType::F32) })
                .collect(),
        ))),
    });
    Ty::Record(fields)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_of_muon_schema() {
        let l = muon_event_schema().layout();
        assert_eq!(l.lists, vec!["muons"]);
        assert_eq!(
            l.leaves
                .iter()
                .map(|(p, _)| p.as_str())
                .collect::<Vec<_>>(),
            vec!["muons.pt", "muons.eta", "muons.phi", "muons.charge", "met"]
        );
        assert_eq!(l.leaves[3].1, PrimType::I32);
    }

    #[test]
    fn layout_of_nested_lists() {
        // Table 2's list-of-lists-of-pairs.
        let ty = Ty::record(vec![(
            "outer",
            Ty::list(Ty::list(Ty::record(vec![
                ("first", Ty::Prim(PrimType::I64)),
                ("second", Ty::Prim(PrimType::I64)),
            ]))),
        )]);
        let l = ty.layout();
        assert_eq!(l.lists, vec!["outer", "outer[]"]); // two list levels, unique keys
        assert_eq!(l.leaves.len(), 2);
    }

    #[test]
    fn schema_json_roundtrip() {
        for ty in [muon_event_schema(), jet_event_schema(95)] {
            let j = ty.to_json();
            let back = Ty::from_json(&j).unwrap();
            assert_eq!(back, ty);
        }
    }

    #[test]
    fn resolve_paths() {
        let s = muon_event_schema();
        assert_eq!(s.resolve("muons.pt"), Some(&Ty::Prim(PrimType::F32)));
        assert_eq!(s.resolve("met"), Some(&Ty::Prim(PrimType::F32)));
        assert!(s.resolve("nope").is_none());
    }

    #[test]
    fn jet_schema_has_95_branches() {
        let l = jet_event_schema(95).layout();
        assert_eq!(l.leaves.len(), 95);
        assert_eq!(l.lists, vec!["jets"]);
    }

    #[test]
    fn display_is_readable() {
        let s = muon_event_schema().to_string();
        assert!(s.contains("muons: [{pt: f32"));
    }
}
