//! Exploded column storage: typed content arrays + offsets arrays.
//!
//! A `ColumnSet` is the in-memory form of a dataset partition: one `Array`
//! per schema leaf ("branch") and one `Vec<i64>` of offsets per list level —
//! the paper's Table-2 representation. Queries run directly on these arrays
//! without ever materializing event objects.

use super::schema::{PrimType, Ty};
use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub enum Array {
    F32(Vec<f32>),
    F64(Vec<f64>),
    I32(Vec<i32>),
    I64(Vec<i64>),
    Bool(Vec<u8>),
}

impl Array {
    pub fn new(p: PrimType) -> Array {
        match p {
            PrimType::F32 => Array::F32(Vec::new()),
            PrimType::F64 => Array::F64(Vec::new()),
            PrimType::I32 => Array::I32(Vec::new()),
            PrimType::I64 => Array::I64(Vec::new()),
            PrimType::Bool => Array::Bool(Vec::new()),
        }
    }

    pub fn prim(&self) -> PrimType {
        match self {
            Array::F32(_) => PrimType::F32,
            Array::F64(_) => PrimType::F64,
            Array::I32(_) => PrimType::I32,
            Array::I64(_) => PrimType::I64,
            Array::Bool(_) => PrimType::Bool,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Array::F32(v) => v.len(),
            Array::F64(v) => v.len(),
            Array::I32(v) => v.len(),
            Array::I64(v) => v.len(),
            Array::Bool(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn byte_len(&self) -> usize {
        self.len() * self.prim().byte_width()
    }

    /// Element as f64 (lossless for all but huge i64) — used by interpreters.
    #[inline]
    pub fn get_f64(&self, i: usize) -> f64 {
        match self {
            Array::F32(v) => v[i] as f64,
            Array::F64(v) => v[i],
            Array::I32(v) => v[i] as f64,
            Array::I64(v) => v[i] as f64,
            Array::Bool(v) => v[i] as f64,
        }
    }

    pub fn as_f32(&self) -> Option<&[f32]> {
        match self {
            Array::F32(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_i32(&self) -> Option<&[i32]> {
        match self {
            Array::I32(v) => Some(v),
            _ => None,
        }
    }

    pub fn push_f64(&mut self, x: f64) {
        match self {
            Array::F32(v) => v.push(x as f32),
            Array::F64(v) => v.push(x),
            Array::I32(v) => v.push(x as i32),
            Array::I64(v) => v.push(x as i64),
            Array::Bool(v) => v.push(if x != 0.0 { 1 } else { 0 }),
        }
    }

    /// Raw little-endian bytes (for the file format).
    pub fn to_bytes(&self) -> Vec<u8> {
        match self {
            Array::F32(v) => v.iter().flat_map(|x| x.to_le_bytes()).collect(),
            Array::F64(v) => v.iter().flat_map(|x| x.to_le_bytes()).collect(),
            Array::I32(v) => v.iter().flat_map(|x| x.to_le_bytes()).collect(),
            Array::I64(v) => v.iter().flat_map(|x| x.to_le_bytes()).collect(),
            Array::Bool(v) => v.clone(),
        }
    }

    pub fn from_bytes(p: PrimType, bytes: &[u8]) -> Result<Array, String> {
        let w = p.byte_width();
        if bytes.len() % w != 0 {
            return Err(format!(
                "byte length {} not a multiple of width {w}",
                bytes.len()
            ));
        }
        let n = bytes.len() / w;
        Ok(match p {
            PrimType::F32 => Array::F32(
                (0..n)
                    .map(|i| f32::from_le_bytes(bytes[i * 4..i * 4 + 4].try_into().unwrap()))
                    .collect(),
            ),
            PrimType::F64 => Array::F64(
                (0..n)
                    .map(|i| f64::from_le_bytes(bytes[i * 8..i * 8 + 8].try_into().unwrap()))
                    .collect(),
            ),
            PrimType::I32 => Array::I32(
                (0..n)
                    .map(|i| i32::from_le_bytes(bytes[i * 4..i * 4 + 4].try_into().unwrap()))
                    .collect(),
            ),
            PrimType::I64 => Array::I64(
                (0..n)
                    .map(|i| i64::from_le_bytes(bytes[i * 8..i * 8 + 8].try_into().unwrap()))
                    .collect(),
            ),
            PrimType::Bool => Array::Bool(bytes.to_vec()),
        })
    }

    /// Slice [lo, hi) into a new Array (used by partitioning).
    pub fn slice(&self, lo: usize, hi: usize) -> Array {
        match self {
            Array::F32(v) => Array::F32(v[lo..hi].to_vec()),
            Array::F64(v) => Array::F64(v[lo..hi].to_vec()),
            Array::I32(v) => Array::I32(v[lo..hi].to_vec()),
            Array::I64(v) => Array::I64(v[lo..hi].to_vec()),
            Array::Bool(v) => Array::Bool(v[lo..hi].to_vec()),
        }
    }

    /// Append `src[lo..hi]` losslessly (used by event reordering). Panics
    /// on a type mismatch — callers copy between arrays of one leaf.
    pub fn append_range(&mut self, src: &Array, lo: usize, hi: usize) {
        match (self, src) {
            (Array::F32(d), Array::F32(s)) => d.extend_from_slice(&s[lo..hi]),
            (Array::F64(d), Array::F64(s)) => d.extend_from_slice(&s[lo..hi]),
            (Array::I32(d), Array::I32(s)) => d.extend_from_slice(&s[lo..hi]),
            (Array::I64(d), Array::I64(s)) => d.extend_from_slice(&s[lo..hi]),
            (Array::Bool(d), Array::Bool(s)) => d.extend_from_slice(&s[lo..hi]),
            (d, s) => panic!("append_range: {:?} <- {:?}", d.prim(), s.prim()),
        }
    }
}

/// A set of exploded columns for `n_events` events.
#[derive(Clone, Debug, PartialEq)]
pub struct ColumnSet {
    pub schema: Ty,
    pub n_events: usize,
    /// list path (layout key) → offsets array of length (#outer items + 1).
    pub offsets: BTreeMap<String, Vec<i64>>,
    /// leaf path → content array.
    pub leaves: BTreeMap<String, Array>,
}

/// A zero-copy event window `[ev_lo, ev_hi)` over a `ColumnSet` — the
/// morsel primitive of the parallel executor.
///
/// Unlike `partition`, nothing is sliced or rebased: content arrays and
/// offsets stay global, and consumers index them with absolute event/item
/// indices bounded by the window (`offsets[ev_lo] .. offsets[ev_hi]` for a
/// list's items). Constructing one is a couple of machine words, so a
/// partition can be cut into thousands of cache-sized morsels for free.
#[derive(Clone, Copy, Debug)]
pub struct ColumnRange<'a> {
    pub cs: &'a ColumnSet,
    pub ev_lo: usize,
    pub ev_hi: usize,
}

impl<'a> ColumnRange<'a> {
    /// Events in the window.
    pub fn n_events(&self) -> usize {
        self.ev_hi - self.ev_lo
    }
}

impl ColumnSet {
    pub fn empty(schema: Ty) -> ColumnSet {
        let layout = schema.layout();
        let mut offsets = BTreeMap::new();
        for l in &layout.lists {
            offsets.insert(l.clone(), vec![0i64]);
        }
        let mut leaves = BTreeMap::new();
        for (p, prim) in &layout.leaves {
            leaves.insert(p.clone(), Array::new(*prim));
        }
        ColumnSet {
            schema,
            n_events: 0,
            offsets,
            leaves,
        }
    }

    pub fn leaf(&self, path: &str) -> Option<&Array> {
        self.leaves.get(path)
    }

    pub fn offsets_of(&self, list_path: &str) -> Option<&[i64]> {
        self.offsets.get(list_path).map(|v| v.as_slice())
    }

    /// Total bytes across all arrays (cache accounting).
    pub fn byte_size(&self) -> usize {
        let leaf_bytes: usize = self.leaves.values().map(|a| a.byte_len()).sum();
        let off_bytes: usize = self.offsets.values().map(|o| o.len() * 8).sum();
        leaf_bytes + off_bytes
    }

    /// Check structural invariants: offsets monotone, starting at 0, and the
    /// lengths of sibling leaf arrays under each list agree.
    pub fn validate(&self) -> Result<(), String> {
        let layout = self.schema.layout();
        for key in &layout.lists {
            let off = self
                .offsets
                .get(key)
                .ok_or_else(|| format!("missing offsets '{key}'"))?;
            if off.first() != Some(&0) {
                return Err(format!("offsets '{key}' must start at 0"));
            }
            if off.windows(2).any(|w| w[1] < w[0]) {
                return Err(format!("offsets '{key}' not monotone"));
            }
        }
        // Every leaf under a list must have length == *offsets.last().
        for (path, _) in &layout.leaves {
            let arr = self
                .leaves
                .get(path)
                .ok_or_else(|| format!("missing leaf '{path}'"))?;
            match self.innermost_list_of(path, &layout) {
                Some(list_key) => {
                    let want = *self.offsets[&list_key].last().unwrap() as usize;
                    if arr.len() != want {
                        return Err(format!(
                            "leaf '{path}' has {} items, offsets imply {want}",
                            arr.len()
                        ));
                    }
                }
                None => {
                    if arr.len() != self.n_events {
                        return Err(format!(
                            "event-level leaf '{path}' has {} items for {} events",
                            arr.len(),
                            self.n_events
                        ));
                    }
                }
            }
        }
        // The outermost offsets arrays must cover exactly n_events.
        for key in &layout.lists {
            if !key.contains("[]") && !key.contains('.') {
                let off = &self.offsets[key];
                if off.len() != self.n_events + 1 {
                    return Err(format!(
                        "offsets '{key}' length {} != n_events+1 {}",
                        off.len(),
                        self.n_events + 1
                    ));
                }
            }
        }
        Ok(())
    }

    /// The innermost list key governing a leaf path, if any.
    fn innermost_list_of(&self, leaf: &str, layout: &super::schema::Layout) -> Option<String> {
        let mut best: Option<&str> = None;
        for key in &layout.lists {
            let base = key.trim_end_matches("[]");
            if leaf == base || leaf.starts_with(&format!("{base}.")) {
                match best {
                    Some(b) if key.len() <= b.len() => {}
                    _ => best = Some(key),
                }
            }
        }
        best.map(|s| s.to_string())
    }

    /// Zero-copy view of the event window `[ev_lo, ev_hi)`.
    pub fn range(&self, ev_lo: usize, ev_hi: usize) -> ColumnRange<'_> {
        assert!(
            ev_lo <= ev_hi && ev_hi <= self.n_events,
            "bad event range [{ev_lo}, {ev_hi}) of {}",
            self.n_events
        );
        ColumnRange {
            cs: self,
            ev_lo,
            ev_hi,
        }
    }

    /// Split into event-range slices of at most `events_per_part` events.
    /// Only supports schemas whose lists are event-level (depth 1), which is
    /// true for all the physics schemas in this repo.
    pub fn partition(&self, events_per_part: usize) -> Vec<ColumnSet> {
        assert!(events_per_part > 0);
        let layout = self.schema.layout();
        let mut parts = Vec::new();
        let mut ev = 0usize;
        while ev < self.n_events {
            let hi = (ev + events_per_part).min(self.n_events);
            let mut offsets = BTreeMap::new();
            for key in &layout.lists {
                let off = &self.offsets[key];
                let base = off[ev];
                let sliced: Vec<i64> = off[ev..=hi].iter().map(|o| o - base).collect();
                offsets.insert(key.clone(), sliced);
            }
            let mut leaves = BTreeMap::new();
            for (path, _) in &layout.leaves {
                let arr = &self.leaves[path];
                match self.innermost_list_of(path, &layout) {
                    Some(key) => {
                        let off = &self.offsets[&key];
                        let lo = off[ev] as usize;
                        let hi_c = off[hi] as usize;
                        leaves.insert(path.clone(), arr.slice(lo, hi_c));
                    }
                    None => {
                        leaves.insert(path.clone(), arr.slice(ev, hi));
                    }
                }
            }
            parts.push(ColumnSet {
                schema: self.schema.clone(),
                n_events: hi - ev,
                offsets,
                leaves,
            });
            ev = hi;
        }
        parts
    }

    /// Reorder events ascending by a physics key — the value of an
    /// event-level leaf (`met`, a run number) or, for an item leaf
    /// (`muons.pt`), the event's maximum of it (empty events sort first,
    /// NaN values are ignored). Event integrity is preserved: each event's
    /// items move together, so the result is the same physics in a
    /// **clustered layout** that zone-map min/max statistics can actually
    /// prune (see `docs/QUERY_LANGUAGE.md` on clustering).
    pub fn order_events_by(&self, leaf: &str) -> Result<ColumnSet, String> {
        let layout = self.schema.layout();
        let arr = self
            .leaves
            .get(leaf)
            .ok_or_else(|| format!("no leaf '{leaf}' to order by"))?;
        let mut keys: Vec<f64> = Vec::with_capacity(self.n_events);
        match self.innermost_list_of(leaf, &layout) {
            None => {
                for ev in 0..self.n_events {
                    keys.push(arr.get_f64(ev));
                }
            }
            Some(key_list) => {
                let off = &self.offsets[&key_list];
                for ev in 0..self.n_events {
                    let mut k = f64::NEG_INFINITY;
                    for i in off[ev] as usize..off[ev + 1] as usize {
                        let v = arr.get_f64(i);
                        if v > k {
                            k = v;
                        }
                    }
                    keys.push(k);
                }
            }
        }
        let mut perm: Vec<usize> = (0..self.n_events).collect();
        perm.sort_by(|&a, &b| keys[a].total_cmp(&keys[b]));
        Ok(self.reorder_events(&perm))
    }

    /// Rebuild the set with events in `perm` order (each event's items
    /// stay contiguous and in their original in-event order).
    fn reorder_events(&self, perm: &[usize]) -> ColumnSet {
        let layout = self.schema.layout();
        let mut offsets = BTreeMap::new();
        for key in &layout.lists {
            let off = &self.offsets[key];
            let mut new_off = Vec::with_capacity(off.len());
            new_off.push(0i64);
            let mut acc = 0i64;
            for &ev in perm {
                acc += off[ev + 1] - off[ev];
                new_off.push(acc);
            }
            offsets.insert(key.clone(), new_off);
        }
        let mut leaves = BTreeMap::new();
        for (path, _) in &layout.leaves {
            let src = &self.leaves[path];
            let mut dst = Array::new(src.prim());
            match self.innermost_list_of(path, &layout) {
                Some(key) => {
                    let off = &self.offsets[&key];
                    for &ev in perm {
                        dst.append_range(src, off[ev] as usize, off[ev + 1] as usize);
                    }
                }
                None => {
                    for &ev in perm {
                        dst.append_range(src, ev, ev + 1);
                    }
                }
            }
            leaves.insert(path.clone(), dst);
        }
        ColumnSet {
            schema: self.schema.clone(),
            n_events: self.n_events,
            offsets,
            leaves,
        }
    }

    /// Keep only the named leaves (and the offsets they need) — the "slim
    /// dataset" operation of Figure 1.
    pub fn project(&self, keep_leaves: &[&str]) -> ColumnSet {
        let layout = self.schema.layout();
        let keep: Vec<String> = keep_leaves.iter().map(|s| s.to_string()).collect();
        let schema = project_schema(&self.schema, "", &keep);
        let mut leaves = BTreeMap::new();
        for (path, _) in &layout.leaves {
            if keep.contains(path) {
                leaves.insert(path.clone(), self.leaves[path].clone());
            }
        }
        let new_layout = schema.layout();
        let mut offsets = BTreeMap::new();
        for key in &new_layout.lists {
            offsets.insert(key.clone(), self.offsets[key].clone());
        }
        ColumnSet {
            schema,
            n_events: self.n_events,
            offsets,
            leaves,
        }
    }
}

fn project_schema(ty: &Ty, prefix: &str, keep: &[String]) -> Ty {
    match ty {
        Ty::Prim(p) => Ty::Prim(*p),
        Ty::List(inner) => Ty::List(Box::new(project_schema(inner, prefix, keep))),
        Ty::Record(fields) => Ty::Record(
            fields
                .iter()
                .filter_map(|f| {
                    let child = if prefix.is_empty() {
                        f.name.clone()
                    } else {
                        format!("{prefix}.{}", f.name)
                    };
                    let keeps_under =
                        keep.iter().any(|k| *k == child || k.starts_with(&format!("{child}.")));
                    if keeps_under {
                        Some(super::schema::Field {
                            name: f.name.clone(),
                            ty: project_schema(&f.ty, &child, keep),
                        })
                    } else {
                        None
                    }
                })
                .collect(),
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::columnar::schema::muon_event_schema;

    fn tiny() -> ColumnSet {
        // 3 events with 2, 0, 1 muons.
        let schema = muon_event_schema();
        let mut cs = ColumnSet::empty(schema);
        cs.n_events = 3;
        cs.offsets.insert("muons".into(), vec![0, 2, 2, 3]);
        cs.leaves
            .insert("muons.pt".into(), Array::F32(vec![50.0, 30.0, 22.0]));
        cs.leaves
            .insert("muons.eta".into(), Array::F32(vec![0.1, -1.2, 2.0]));
        cs.leaves
            .insert("muons.phi".into(), Array::F32(vec![0.0, 1.0, 2.0]));
        cs.leaves
            .insert("muons.charge".into(), Array::I32(vec![1, -1, 1]));
        cs.leaves
            .insert("met".into(), Array::F32(vec![12.0, 8.0, 40.0]));
        cs
    }

    #[test]
    fn validate_ok() {
        tiny().validate().unwrap();
    }

    #[test]
    fn validate_catches_length_mismatch() {
        let mut cs = tiny();
        cs.leaves
            .insert("muons.pt".into(), Array::F32(vec![1.0]));
        assert!(cs.validate().is_err());
    }

    #[test]
    fn validate_catches_bad_offsets() {
        let mut cs = tiny();
        cs.offsets.insert("muons".into(), vec![0, 3, 2, 3]);
        assert!(cs.validate().is_err());
    }

    #[test]
    fn bytes_roundtrip() {
        for arr in [
            Array::F32(vec![1.5, -2.25]),
            Array::F64(vec![1.5e300, -1.0]),
            Array::I32(vec![i32::MIN, 7]),
            Array::I64(vec![i64::MAX, -9]),
            Array::Bool(vec![0, 1, 1]),
        ] {
            let b = arr.to_bytes();
            assert_eq!(Array::from_bytes(arr.prim(), &b).unwrap(), arr);
        }
    }

    #[test]
    fn partition_preserves_content() {
        let cs = tiny();
        let parts = cs.partition(2);
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[0].n_events, 2);
        assert_eq!(parts[1].n_events, 1);
        parts[0].validate().unwrap();
        parts[1].validate().unwrap();
        assert_eq!(parts[0].offsets_of("muons").unwrap(), &[0, 2, 2]);
        assert_eq!(parts[1].offsets_of("muons").unwrap(), &[0, 1]);
        assert_eq!(
            parts[1].leaf("muons.pt").unwrap().as_f32().unwrap(),
            &[22.0]
        );
        assert_eq!(parts[1].leaf("met").unwrap().as_f32().unwrap(), &[40.0]);
    }

    #[test]
    fn order_events_by_clusters_without_losing_events() {
        let cs = tiny();
        // Max pts per event: 50, -inf (empty), 22 → ascending [ev1, ev2, ev0].
        let by_pt = cs.order_events_by("muons.pt").unwrap();
        by_pt.validate().unwrap();
        assert_eq!(by_pt.offsets_of("muons").unwrap(), &[0, 0, 1, 3]);
        assert_eq!(
            by_pt.leaf("muons.pt").unwrap().as_f32().unwrap(),
            &[22.0, 50.0, 30.0]
        );
        // Event-level leaves ride along with their event.
        assert_eq!(by_pt.leaf("met").unwrap().as_f32().unwrap(), &[8.0, 40.0, 12.0]);
        // Ordering by an event-level key.
        let by_met = cs.order_events_by("met").unwrap();
        by_met.validate().unwrap();
        assert_eq!(by_met.leaf("met").unwrap().as_f32().unwrap(), &[8.0, 12.0, 40.0]);
        assert_eq!(by_met.offsets_of("muons").unwrap(), &[0, 0, 2, 3]);
        assert!(cs.order_events_by("nope").is_err());
    }

    #[test]
    fn project_slims_dataset() {
        let cs = tiny();
        let slim = cs.project(&["muons.pt"]);
        slim.validate().unwrap();
        assert!(slim.leaf("muons.pt").is_some());
        assert!(slim.leaf("muons.eta").is_none());
        assert!(slim.leaf("met").is_none());
        assert_eq!(slim.offsets_of("muons").unwrap(), cs.offsets_of("muons").unwrap());
        assert!(slim.byte_size() < cs.byte_size());
    }

    #[test]
    fn range_views_are_windows_not_copies() {
        let cs = tiny();
        let v = cs.range(1, 3);
        assert_eq!(v.n_events(), 2);
        // Absolute indexing: the view shares the parent's arrays verbatim.
        assert!(std::ptr::eq(v.cs, &cs));
        assert_eq!(v.cs.offsets_of("muons").unwrap()[v.ev_lo], 2);
        assert_eq!(v.cs.offsets_of("muons").unwrap()[v.ev_hi], 3);
        // Adjacent windows tile the full set.
        let full = cs.range(0, cs.n_events);
        assert_eq!(full.n_events(), cs.n_events);
    }

    #[test]
    #[should_panic(expected = "bad event range")]
    fn range_rejects_out_of_bounds() {
        let cs = tiny();
        let _ = cs.range(0, 4);
    }

    #[test]
    fn get_f64_across_types() {
        let cs = tiny();
        assert_eq!(cs.leaf("muons.charge").unwrap().get_f64(1), -1.0);
        assert_eq!(cs.leaf("met").unwrap().get_f64(2), 40.0);
    }
}
