//! Object ⇄ exploded-array conversion ("splitting" / GetEntry).
//!
//! `explode` turns a vector of event objects into the flat arrays of
//! Table 2; `materialize` is the inverse — the expensive object
//! materialization step that the paper's query path *avoids* and which our
//! baselines (`engine::object_baseline`) deliberately perform.

use super::arrays::{Array, ColumnSet};
use super::schema::Ty;

/// A dynamically-typed event object (the "physicist's view").
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    F64(f64),
    I64(i64),
    Bool(bool),
    List(Vec<Value>),
    Rec(Vec<(String, Value)>),
}

impl Value {
    pub fn rec(fields: Vec<(&str, Value)>) -> Value {
        Value::Rec(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn get(&self, name: &str) -> Option<&Value> {
        match self {
            Value::Rec(fs) => fs.iter().find(|(k, _)| k == name).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::F64(x) => Some(*x),
            Value::I64(x) => Some(*x as f64),
            Value::Bool(b) => Some(*b as i64 as f64),
            _ => None,
        }
    }

    pub fn as_list(&self) -> Option<&[Value]> {
        match self {
            Value::List(v) => Some(v),
            _ => None,
        }
    }
}

/// Explode event objects into columnar arrays according to `schema`.
pub fn explode(schema: &Ty, events: &[Value]) -> Result<ColumnSet, String> {
    let mut cs = ColumnSet::empty(schema.clone());
    cs.n_events = events.len();
    for ev in events {
        push_value(schema, ev, "", 0, &mut cs)?;
    }
    cs.validate()?;
    Ok(cs)
}

fn push_value(
    ty: &Ty,
    v: &Value,
    prefix: &str,
    list_depth: usize,
    cs: &mut ColumnSet,
) -> Result<(), String> {
    match (ty, v) {
        (Ty::Prim(_), v) => {
            let x = v
                .as_f64()
                .ok_or_else(|| format!("expected primitive at '{prefix}', got {v:?}"))?;
            cs.leaves
                .get_mut(prefix)
                .ok_or_else(|| format!("no leaf '{prefix}'"))?
                .push_f64(x);
            Ok(())
        }
        (Ty::List(inner), Value::List(items)) => {
            let key = if list_depth == 0 {
                prefix.to_string()
            } else {
                format!("{prefix}{}", "[]".repeat(list_depth))
            };
            for item in items {
                push_value(inner, item, prefix, list_depth + 1, cs)?;
            }
            let off = cs
                .offsets
                .get_mut(&key)
                .ok_or_else(|| format!("no offsets '{key}'"))?;
            let last = *off.last().unwrap();
            off.push(last + items.len() as i64);
            Ok(())
        }
        (Ty::Record(fields), Value::Rec(_)) => {
            for f in fields {
                let child = if prefix.is_empty() {
                    f.name.clone()
                } else {
                    format!("{prefix}.{}", f.name)
                };
                let fv = v
                    .get(&f.name)
                    .ok_or_else(|| format!("missing field '{}' at '{prefix}'", f.name))?;
                push_value(&f.ty, fv, &child, 0, cs)?;
            }
            Ok(())
        }
        (t, v) => Err(format!("type mismatch at '{prefix}': {t} vs {v:?}")),
    }
}

/// Materialize event `i` from the exploded arrays (inverse of `explode`).
pub fn materialize(cs: &ColumnSet, i: usize) -> Result<Value, String> {
    let mut cursor = Cursors::at_event(cs, i)?;
    read_value(&cs.schema, "", 0, cs, &mut cursor)
}

/// Materialize every event.
pub fn materialize_all(cs: &ColumnSet) -> Result<Vec<Value>, String> {
    (0..cs.n_events).map(|i| materialize(cs, i)).collect()
}

/// Per-array read positions during materialization. For event `i`, leaf and
/// offsets cursors start at the positions implied by the outer offsets.
struct Cursors {
    /// For event-level access: the event index.
    event: usize,
}

impl Cursors {
    fn at_event(_cs: &ColumnSet, i: usize) -> Result<Cursors, String> {
        Ok(Cursors { event: i })
    }
}

fn read_value(
    ty: &Ty,
    prefix: &str,
    list_depth: usize,
    cs: &ColumnSet,
    cur: &mut Cursors,
) -> Result<Value, String> {
    read_at(ty, prefix, list_depth, cs, cur.event as i64)
}

/// Read the value of `ty` at logical index `idx` within its container level.
fn read_at(
    ty: &Ty,
    prefix: &str,
    list_depth: usize,
    cs: &ColumnSet,
    idx: i64,
) -> Result<Value, String> {
    match ty {
        Ty::Prim(_) => {
            let arr = cs
                .leaf(prefix)
                .ok_or_else(|| format!("no leaf '{prefix}'"))?;
            let x = arr.get_f64(idx as usize);
            Ok(match arr {
                Array::I32(_) | Array::I64(_) => Value::I64(x as i64),
                Array::Bool(_) => Value::Bool(x != 0.0),
                _ => Value::F64(x),
            })
        }
        Ty::List(inner) => {
            let key = if list_depth == 0 {
                prefix.to_string()
            } else {
                format!("{prefix}{}", "[]".repeat(list_depth))
            };
            let off = cs
                .offsets_of(&key)
                .ok_or_else(|| format!("no offsets '{key}'"))?;
            let lo = off[idx as usize];
            let hi = off[idx as usize + 1];
            let mut items = Vec::with_capacity((hi - lo) as usize);
            for j in lo..hi {
                items.push(read_at(inner, prefix, list_depth + 1, cs, j)?);
            }
            Ok(Value::List(items))
        }
        Ty::Record(fields) => {
            let mut out = Vec::with_capacity(fields.len());
            for f in fields {
                let child = if prefix.is_empty() {
                    f.name.clone()
                } else {
                    format!("{prefix}.{}", f.name)
                };
                out.push((f.name.clone(), read_at(&f.ty, &child, 0, cs, idx)?));
            }
            Ok(Value::Rec(out))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::columnar::schema::{muon_event_schema, PrimType};

    /// The paper's Table 2: `[[(a,1),(b,2),(c,3)],[],[(d,4)]]` and
    /// `[[(e,5),(f,6)]]` as a dataset of two outer values, encoded as four
    /// flat arrays.
    #[test]
    fn table2_exact_encoding() {
        let schema = Ty::record(vec![(
            "outer",
            Ty::list(Ty::list(Ty::record(vec![
                ("first", Ty::Prim(PrimType::I64)),
                ("second", Ty::Prim(PrimType::I64)),
            ]))),
        )]);
        let ch = |c: char| Value::I64(c as i64);
        let pair = |c: char, n: i64| {
            Value::rec(vec![("first", ch(c)), ("second", Value::I64(n))])
        };
        let ev1 = Value::rec(vec![(
            "outer",
            Value::List(vec![
                Value::List(vec![pair('a', 1), pair('b', 2), pair('c', 3)]),
                Value::List(vec![]),
                Value::List(vec![pair('d', 4)]),
            ]),
        )]);
        let ev2 = Value::rec(vec![(
            "outer",
            Value::List(vec![Value::List(vec![pair('e', 5), pair('f', 6)])]),
        )]);
        let cs = explode(&schema, &[ev1.clone(), ev2.clone()]).unwrap();

        // Outer offsets: event boundaries in units of inner lists.
        assert_eq!(cs.offsets_of("outer").unwrap(), &[0, 3, 4]);
        // Inner offsets: inner-list boundaries in units of pairs.
        assert_eq!(cs.offsets_of("outer[]").unwrap(), &[0, 3, 3, 4, 6]);
        // Attribute arrays, flat.
        let first: Vec<i64> = match cs.leaf("outer.first").unwrap() {
            Array::I64(v) => v.clone(),
            _ => panic!(),
        };
        assert_eq!(
            first,
            vec!['a' as i64, 'b' as i64, 'c' as i64, 'd' as i64, 'e' as i64, 'f' as i64]
        );
        let second: Vec<i64> = match cs.leaf("outer.second").unwrap() {
            Array::I64(v) => v.clone(),
            _ => panic!(),
        };
        assert_eq!(second, vec![1, 2, 3, 4, 5, 6]);

        // Round-trip.
        assert_eq!(materialize(&cs, 0).unwrap(), ev1);
        assert_eq!(materialize(&cs, 1).unwrap(), ev2);
    }

    #[test]
    fn muon_roundtrip() {
        let schema = muon_event_schema();
        let mu = |pt: f64, eta: f64, phi: f64, q: i64| {
            Value::rec(vec![
                ("pt", Value::F64(pt)),
                ("eta", Value::F64(eta)),
                ("phi", Value::F64(phi)),
                ("charge", Value::I64(q)),
            ])
        };
        let events = vec![
            Value::rec(vec![
                ("muons", Value::List(vec![mu(50.0, 0.5, 1.0, 1), mu(30.0, -1.0, 2.0, -1)])),
                ("met", Value::F64(15.0)),
            ]),
            Value::rec(vec![("muons", Value::List(vec![])), ("met", Value::F64(3.0))]),
        ];
        let cs = explode(&schema, &events).unwrap();
        assert_eq!(cs.n_events, 2);
        assert_eq!(cs.offsets_of("muons").unwrap(), &[0, 2, 2]);
        // f32 storage truncation is fine for these values.
        let back = materialize_all(&cs).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(
            back[0].get("muons").unwrap().as_list().unwrap().len(),
            2
        );
        assert_eq!(back[1].get("met").unwrap().as_f64(), Some(3.0));
        assert_eq!(
            back[0].get("muons").unwrap().as_list().unwrap()[0]
                .get("pt")
                .unwrap()
                .as_f64(),
            Some(50.0)
        );
    }

    #[test]
    fn explode_rejects_schema_mismatch() {
        let schema = muon_event_schema();
        let bad = Value::rec(vec![("muons", Value::F64(1.0)), ("met", Value::F64(0.0))]);
        assert!(explode(&schema, &[bad]).is_err());
    }

    #[test]
    fn explode_rejects_missing_field() {
        let schema = muon_event_schema();
        let bad = Value::rec(vec![("muons", Value::List(vec![]))]);
        assert!(explode(&schema, &[bad]).is_err());
    }
}
