//! Exploded (columnar) data model for hierarchically nested event data —
//! the paper's Table-2 representation: one content array per attribute and
//! one offsets array per list level.

pub mod arrays;
pub mod explode;
pub mod schema;

pub use arrays::{Array, ColumnSet};
pub use explode::{explode, materialize, materialize_all, Value};
pub use schema::{muon_event_schema, jet_event_schema, ttbar_event_schema, Field, Layout, PrimType, Ty};
