//! Synthetic tt̄-like jet sample — the Table-1 dataset.
//!
//! The paper's Table 1 fills one histogram of jet pT from a tt̄ sample whose
//! jets carry **95 branches**; the experiment's point is the cost of loading
//! 95 branches versus loading only `jets.pt`. We reproduce the shape:
//! events with a realistic jet multiplicity (tt̄ → ~6 jets + radiation),
//! falling pT spectra, and 91 auxiliary per-jet attributes (b-tag
//! discriminants, constituent counts, energy fractions... here: generic
//! floats) for a total of 95 per-jet branches.
//!
//! Events additionally carry a small muon list (semileptonic tt̄: usually
//! 0–2 leptons, *empty for many events*) drawn from an RNG stream
//! independent of the jet stream, so adding muons left every jet array
//! bit-identical to earlier seeds. The second list is what AGC-style
//! cross-list queries (muon × jet pairs, `muons[n-1]`-style gathers over
//! possibly-empty lists) exercise.

use crate::columnar::arrays::{Array, ColumnSet};
use crate::columnar::schema::ttbar_event_schema;
use crate::util::rng::Pcg32;
use std::collections::BTreeMap;
use std::f64::consts::PI;

pub const N_JET_ATTRS: usize = 95;
pub const MAX_JETS: usize = 20;
pub const MAX_MUONS: usize = 6;

/// XOR'd into the seed for the muon stream so it never correlates with —
/// or perturbs — the jet stream.
const MUON_STREAM: u64 = 0x9e37_79b9_7f4a_7c15;

/// Generate `n_events` tt̄-like events with `n_attrs` per-jet branches.
pub fn generate_ttbar(n_events: usize, n_attrs: usize, seed: u64) -> ColumnSet {
    assert!(n_attrs >= 4, "need at least pt/eta/phi/mass");
    let mut rng = Pcg32::new(seed);
    let mut mrng = Pcg32::new(seed ^ MUON_STREAM);
    let schema = ttbar_event_schema(n_attrs);
    let layout = schema.layout();

    let mut offsets: Vec<i64> = Vec::with_capacity(n_events + 1);
    offsets.push(0);
    let cap = n_events * 6 + 16;
    let mut cols: Vec<Vec<f32>> = (0..n_attrs).map(|_| Vec::with_capacity(cap)).collect();

    let mut moffsets: Vec<i64> = Vec::with_capacity(n_events + 1);
    moffsets.push(0);
    let mut mu_pt: Vec<f32> = Vec::with_capacity(n_events * 2);
    let mut mu_eta: Vec<f32> = Vec::with_capacity(n_events * 2);
    let mut mu_phi: Vec<f32> = Vec::with_capacity(n_events * 2);

    let mut jet_pts: Vec<f64> = Vec::with_capacity(MAX_JETS);
    for _ in 0..n_events {
        // tt̄: ~2 b-jets + 4 W-jets + Poisson radiation.
        let n_jets = ((2 + rng.poisson(4.0) as usize).min(MAX_JETS)).max(1);
        jet_pts.clear();
        for _ in 0..n_jets {
            jet_pts.push(20.0 + rng.exponential(55.0));
        }
        jet_pts.sort_by(|a, b| b.partial_cmp(a).unwrap());
        for &jpt in jet_pts.iter() {
            cols[0].push(jpt as f32); // pt
            cols[1].push(rng.gauss(0.0, 1.6).clamp(-4.7, 4.7) as f32); // eta
            cols[2].push(rng.uniform(-PI, PI) as f32); // phi
            cols[3].push((rng.gauss(0.12, 0.03) * jpt).max(0.1) as f32); // mass
            for c in cols.iter_mut().take(n_attrs).skip(4) {
                // Generic auxiliary attributes: cheap but non-constant so
                // compression ratios are realistic.
                c.push(rng.f32());
            }
        }
        offsets.push(cols[0].len() as i64);

        // Semileptonic tt̄: ~1 lepton on average, frequently none.
        let n_muons = (mrng.poisson(1.1) as usize).min(MAX_MUONS);
        for _ in 0..n_muons {
            mu_pt.push((15.0 + mrng.exponential(28.0)) as f32);
            mu_eta.push(mrng.gauss(0.0, 1.2).clamp(-2.4, 2.4) as f32);
            mu_phi.push(mrng.uniform(-PI, PI) as f32);
        }
        moffsets.push(mu_pt.len() as i64);
    }

    let mut leaves = BTreeMap::new();
    // The first `n_attrs` layout leaves are the jet branches (schema field
    // order puts `jets` before `muons`); the muon leaves go in by name.
    for ((path, _), col) in layout.leaves.iter().zip(cols.into_iter()) {
        leaves.insert(path.clone(), Array::F32(col));
    }
    leaves.insert("muons.pt".to_string(), Array::F32(mu_pt));
    leaves.insert("muons.eta".to_string(), Array::F32(mu_eta));
    leaves.insert("muons.phi".to_string(), Array::F32(mu_phi));
    let mut off = BTreeMap::new();
    off.insert("jets".to_string(), offsets);
    off.insert("muons".to_string(), moffsets);

    let cs = ColumnSet {
        schema,
        n_events,
        offsets: off,
        leaves,
    };
    debug_assert!(cs.validate().is_ok());
    cs
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn has_95_jet_branches_plus_muons() {
        let cs = generate_ttbar(100, N_JET_ATTRS, 1);
        cs.validate().unwrap();
        assert_eq!(cs.leaves.len(), 98); // 95 jet branches + muon pt/eta/phi
        assert!(cs.leaf("jets.pt").is_some());
        assert!(cs.leaf("jets.attr94").is_some());
        assert!(cs.leaf("muons.pt").is_some());
    }

    /// The muon stream is independent of the jet stream: jet arrays are
    /// bit-identical to what the pre-muon generator produced, and the muon
    /// list is often empty (the lane family cross-list tests rely on).
    #[test]
    fn muons_ride_an_independent_stream() {
        let cs = generate_ttbar(2000, 5, 9);
        let off = cs.offsets_of("muons").unwrap();
        let mut empty = 0;
        for w in off.windows(2) {
            let n = (w[1] - w[0]) as usize;
            assert!(n <= MAX_MUONS);
            if n == 0 {
                empty += 1;
            }
        }
        assert!(empty > 100, "expected many 0-muon events, got {empty}");
        let avg = cs.leaf("muons.pt").unwrap().len() as f64 / cs.n_events as f64;
        assert!((0.5..2.0).contains(&avg), "avg muons/event {avg}");
        for &pt in cs.leaf("muons.pt").unwrap().as_f32().unwrap() {
            assert!(pt >= 15.0);
        }
    }

    #[test]
    fn deterministic() {
        assert_eq!(generate_ttbar(50, 10, 7), generate_ttbar(50, 10, 7));
    }

    #[test]
    fn jet_multiplicity_realistic() {
        let cs = generate_ttbar(5000, 6, 2);
        let total_jets = cs.leaf("jets.pt").unwrap().len();
        let avg = total_jets as f64 / cs.n_events as f64;
        assert!((4.0..8.5).contains(&avg), "avg jets/event {avg}");
        let off = cs.offsets_of("jets").unwrap();
        for w in off.windows(2) {
            let n = (w[1] - w[0]) as usize;
            assert!((1..=MAX_JETS).contains(&n));
        }
    }

    #[test]
    fn jets_sorted_and_above_threshold() {
        let cs = generate_ttbar(1000, 5, 3);
        let off = cs.offsets_of("jets").unwrap();
        let pt = cs.leaf("jets.pt").unwrap().as_f32().unwrap();
        for w in off.windows(2) {
            for k in w[0]..w[1] {
                assert!(pt[k as usize] >= 20.0);
                if k + 1 < w[1] {
                    assert!(pt[k as usize] >= pt[k as usize + 1]);
                }
            }
        }
    }
}
