//! Synthetic tt̄-like jet sample — the Table-1 dataset.
//!
//! The paper's Table 1 fills one histogram of jet pT from a tt̄ sample whose
//! jets carry **95 branches**; the experiment's point is the cost of loading
//! 95 branches versus loading only `jets.pt`. We reproduce the shape:
//! events with a realistic jet multiplicity (tt̄ → ~6 jets + radiation),
//! falling pT spectra, and 91 auxiliary per-jet attributes (b-tag
//! discriminants, constituent counts, energy fractions... here: generic
//! floats) for a total of 95 per-jet branches.

use crate::columnar::arrays::{Array, ColumnSet};
use crate::columnar::schema::jet_event_schema;
use crate::util::rng::Pcg32;
use std::collections::BTreeMap;
use std::f64::consts::PI;

pub const N_JET_ATTRS: usize = 95;
pub const MAX_JETS: usize = 20;

/// Generate `n_events` tt̄-like events with `n_attrs` per-jet branches.
pub fn generate_ttbar(n_events: usize, n_attrs: usize, seed: u64) -> ColumnSet {
    assert!(n_attrs >= 4, "need at least pt/eta/phi/mass");
    let mut rng = Pcg32::new(seed);
    let schema = jet_event_schema(n_attrs);
    let layout = schema.layout();

    let mut offsets: Vec<i64> = Vec::with_capacity(n_events + 1);
    offsets.push(0);
    let cap = n_events * 6 + 16;
    let mut cols: Vec<Vec<f32>> = (0..n_attrs).map(|_| Vec::with_capacity(cap)).collect();

    let mut jet_pts: Vec<f64> = Vec::with_capacity(MAX_JETS);
    for _ in 0..n_events {
        // tt̄: ~2 b-jets + 4 W-jets + Poisson radiation.
        let n_jets = ((2 + rng.poisson(4.0) as usize).min(MAX_JETS)).max(1);
        jet_pts.clear();
        for _ in 0..n_jets {
            jet_pts.push(20.0 + rng.exponential(55.0));
        }
        jet_pts.sort_by(|a, b| b.partial_cmp(a).unwrap());
        for &jpt in jet_pts.iter() {
            cols[0].push(jpt as f32); // pt
            cols[1].push(rng.gauss(0.0, 1.6).clamp(-4.7, 4.7) as f32); // eta
            cols[2].push(rng.uniform(-PI, PI) as f32); // phi
            cols[3].push((rng.gauss(0.12, 0.03) * jpt).max(0.1) as f32); // mass
            for c in cols.iter_mut().take(n_attrs).skip(4) {
                // Generic auxiliary attributes: cheap but non-constant so
                // compression ratios are realistic.
                c.push(rng.f32());
            }
        }
        offsets.push(cols[0].len() as i64);
    }

    let mut leaves = BTreeMap::new();
    for ((path, _), col) in layout.leaves.iter().zip(cols.into_iter()) {
        leaves.insert(path.clone(), Array::F32(col));
    }
    let mut off = BTreeMap::new();
    off.insert("jets".to_string(), offsets);

    let cs = ColumnSet {
        schema,
        n_events,
        offsets: off,
        leaves,
    };
    debug_assert!(cs.validate().is_ok());
    cs
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn has_95_branches() {
        let cs = generate_ttbar(100, N_JET_ATTRS, 1);
        cs.validate().unwrap();
        assert_eq!(cs.leaves.len(), 95);
        assert!(cs.leaf("jets.pt").is_some());
        assert!(cs.leaf("jets.attr94").is_some());
    }

    #[test]
    fn deterministic() {
        assert_eq!(generate_ttbar(50, 10, 7), generate_ttbar(50, 10, 7));
    }

    #[test]
    fn jet_multiplicity_realistic() {
        let cs = generate_ttbar(5000, 6, 2);
        let total_jets = cs.leaf("jets.pt").unwrap().len();
        let avg = total_jets as f64 / cs.n_events as f64;
        assert!((4.0..8.5).contains(&avg), "avg jets/event {avg}");
        let off = cs.offsets_of("jets").unwrap();
        for w in off.windows(2) {
            let n = (w[1] - w[0]) as usize;
            assert!((1..=MAX_JETS).contains(&n));
        }
    }

    #[test]
    fn jets_sorted_and_above_threshold() {
        let cs = generate_ttbar(1000, 5, 3);
        let off = cs.offsets_of("jets").unwrap();
        let pt = cs.leaf("jets.pt").unwrap().as_f32().unwrap();
        for w in off.windows(2) {
            for k in w[0]..w[1] {
                assert!(pt[k as usize] >= 20.0);
                if k + 1 < w[1] {
                    assert!(pt[k as usize] >= pt[k as usize + 1]);
                }
            }
        }
    }
}
