//! Synthetic physics event generation.
//!
//! The paper's measurements use a simulated Drell-Yan sample (5.4M CMS
//! collisions) for Figure 1 and a tt̄ sample with 95 jet branches for
//! Table 1. Neither is public, so we generate statistically equivalent
//! synthetic datasets: what matters for the reproduced experiments is the
//! *data shape* — variable-length particle lists, realistic multiplicities,
//! branch counts and value distributions — not the detector physics.

pub mod drellyan;
pub mod ttbar;

pub use drellyan::generate_drellyan;
pub use ttbar::generate_ttbar;

/// Four-vector helpers shared by the generators.
pub mod kinematics {
    /// (px, py, pz, E) from pt, eta, phi, m.
    pub fn p4_from_ptetaphim(pt: f64, eta: f64, phi: f64, m: f64) -> [f64; 4] {
        let px = pt * phi.cos();
        let py = pt * phi.sin();
        let pz = pt * eta.sinh();
        let e = (px * px + py * py + pz * pz + m * m).sqrt();
        [px, py, pz, e]
    }

    /// Invariant mass of the sum of two four-vectors.
    pub fn inv_mass(a: [f64; 4], b: [f64; 4]) -> f64 {
        let e = a[3] + b[3];
        let px = a[0] + b[0];
        let py = a[1] + b[1];
        let pz = a[2] + b[2];
        (e * e - px * px - py * py - pz * pz).max(0.0).sqrt()
    }

    /// (pt, eta, phi) of a three-momentum.
    pub fn ptetaphi(p: [f64; 3]) -> (f64, f64, f64) {
        let pt = (p[0] * p[0] + p[1] * p[1]).sqrt();
        let phi = p[1].atan2(p[0]);
        let pmag = (p[0] * p[0] + p[1] * p[1] + p[2] * p[2]).sqrt();
        // eta = atanh(pz/|p|), guarded.
        let cos_theta = if pmag > 0.0 { p[2] / pmag } else { 0.0 };
        let eta = 0.5 * ((1.0 + cos_theta) / (1.0 - cos_theta).max(1e-12)).ln();
        (pt, eta, phi)
    }

    /// Lorentz boost of four-vector `p` by velocity vector `beta`.
    pub fn boost(p: [f64; 4], beta: [f64; 3]) -> [f64; 4] {
        let b2 = beta[0] * beta[0] + beta[1] * beta[1] + beta[2] * beta[2];
        if b2 <= 0.0 {
            return p;
        }
        let gamma = 1.0 / (1.0 - b2).sqrt();
        let bp = beta[0] * p[0] + beta[1] * p[1] + beta[2] * p[2];
        let k = gamma * gamma / (gamma + 1.0) * bp + gamma * p[3];
        [
            p[0] + beta[0] * k,
            p[1] + beta[1] * k,
            p[2] + beta[2] * k,
            gamma * (p[3] + bp),
        ]
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn mass_of_back_to_back_pair() {
            // Two massless particles back-to-back with E=45.6 → m = 91.2.
            let a = p4_from_ptetaphim(45.6, 0.0, 0.0, 0.0);
            let b = p4_from_ptetaphim(45.6, 0.0, std::f64::consts::PI, 0.0);
            assert!((inv_mass(a, b) - 91.2).abs() < 1e-9);
        }

        #[test]
        fn boost_preserves_mass() {
            let p = p4_from_ptetaphim(30.0, 0.7, 1.1, 0.105);
            let q = boost(p, [0.3, -0.2, 0.5]);
            let m2p = p[3] * p[3] - p[0] * p[0] - p[1] * p[1] - p[2] * p[2];
            let m2q = q[3] * q[3] - q[0] * q[0] - q[1] * q[1] - q[2] * q[2];
            assert!((m2p - m2q).abs() < 1e-6, "{m2p} vs {m2q}");
        }

        #[test]
        fn ptetaphi_roundtrip() {
            let p4 = p4_from_ptetaphim(25.0, -1.3, 2.0, 0.0);
            let (pt, eta, phi) = ptetaphi([p4[0], p4[1], p4[2]]);
            assert!((pt - 25.0).abs() < 1e-9);
            assert!((eta - -1.3).abs() < 1e-9);
            assert!((phi - 2.0).abs() < 1e-9);
        }
    }
}
