//! Synthetic Drell-Yan (Z/γ* → μμ) sample — the Figure-1 dataset.
//!
//! Each event is, with probability `Z_FRACTION`, a Z-boson decay to two
//! muons: the Z mass is drawn from a Breit–Wigner around 91.19 GeV, the Z is
//! given a soft transverse momentum and a longitudinal rapidity, and decayed
//! isotropically in its rest frame; the muons are boosted back to the lab
//! and kept if they pass a loose acceptance (pt > 3 GeV, |eta| < 2.4).
//! Background events and extra soft muons fill out the multiplicity
//! distribution. Dimuon mass of the generated sample therefore reconstructs
//! a visible Z peak — which is what `examples/dimuon_spectrum.rs` plots.
//!
//! Generation writes straight into exploded arrays (never builds objects):
//! generating 5.4M events must itself be fast.

use crate::columnar::arrays::{Array, ColumnSet};
use crate::columnar::schema::muon_event_schema;
use crate::datagen::kinematics::{boost, ptetaphi};
use crate::util::rng::Pcg32;
use std::collections::BTreeMap;
use std::f64::consts::PI;

pub const Z_MASS: f64 = 91.19;
pub const Z_WIDTH: f64 = 2.49;
pub const MU_MASS: f64 = 0.105_66;
const Z_FRACTION: f64 = 0.75;
/// Hard cap on muons per event — matches the K=8 padding capacity of the
/// AOT kernels (see DESIGN.md §6).
pub const MAX_MUONS: usize = 8;

/// Generate `n_events` Drell-Yan events into exploded columns.
pub fn generate_drellyan(n_events: usize, seed: u64) -> ColumnSet {
    let mut rng = Pcg32::new(seed);
    let mut offsets: Vec<i64> = Vec::with_capacity(n_events + 1);
    offsets.push(0);
    // Reserve assuming ~2 muons/event.
    let cap = n_events * 2 + 16;
    let mut pt: Vec<f32> = Vec::with_capacity(cap);
    let mut eta: Vec<f32> = Vec::with_capacity(cap);
    let mut phi: Vec<f32> = Vec::with_capacity(cap);
    let mut charge: Vec<i32> = Vec::with_capacity(cap);
    let mut met: Vec<f32> = Vec::with_capacity(n_events);

    let mut scratch: Vec<(f64, f64, f64, i32)> = Vec::with_capacity(MAX_MUONS);

    for _ in 0..n_events {
        scratch.clear();
        if rng.bool_with(Z_FRACTION) {
            gen_z_decay(&mut rng, &mut scratch);
        }
        // Soft / background muons.
        let softs = if scratch.is_empty() {
            rng.poisson(0.8)
        } else {
            rng.poisson(0.3)
        };
        for _ in 0..softs {
            if scratch.len() >= MAX_MUONS {
                break;
            }
            let spt = 2.0 + rng.exponential(5.0);
            let seta = rng.uniform(-2.4, 2.4);
            let sphi = rng.uniform(-PI, PI);
            let q = if rng.bool_with(0.5) { 1 } else { -1 };
            scratch.push((spt, seta, sphi, q));
        }
        // Highest-pt first, like real reco collections.
        scratch.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        for &(mpt, meta_, mphi, q) in scratch.iter() {
            pt.push(mpt as f32);
            eta.push(meta_ as f32);
            phi.push(mphi as f32);
            charge.push(q);
        }
        offsets.push(pt.len() as i64);
        met.push(rng.exponential(15.0) as f32);
    }

    let mut leaves = BTreeMap::new();
    leaves.insert("muons.pt".to_string(), Array::F32(pt));
    leaves.insert("muons.eta".to_string(), Array::F32(eta));
    leaves.insert("muons.phi".to_string(), Array::F32(phi));
    leaves.insert("muons.charge".to_string(), Array::I32(charge));
    leaves.insert("met".to_string(), Array::F32(met));
    let mut off = BTreeMap::new();
    off.insert("muons".to_string(), offsets);

    let cs = ColumnSet {
        schema: muon_event_schema(),
        n_events,
        offsets: off,
        leaves,
    };
    debug_assert!(cs.validate().is_ok());
    cs
}

fn gen_z_decay(rng: &mut Pcg32, out: &mut Vec<(f64, f64, f64, i32)>) {
    let m = rng.breit_wigner(Z_MASS, Z_WIDTH, 40.0, 200.0);
    // Z lab kinematics: soft pT, rapidity spread, uniform phi.
    let zpt = rng.exponential(8.0);
    let zy = rng.gauss(0.0, 1.4);
    let zphi = rng.uniform(-PI, PI);
    let mt = (m * m + zpt * zpt).sqrt();
    let ez = mt * zy.cosh();
    let pz = mt * zy.sinh();
    let zp4 = [zpt * zphi.cos(), zpt * zphi.sin(), pz, ez];
    let beta = [zp4[0] / zp4[3], zp4[1] / zp4[3], zp4[2] / zp4[3]];

    // Isotropic decay in the Z rest frame.
    let cos_t = rng.uniform(-1.0, 1.0);
    let sin_t = (1.0 - cos_t * cos_t).sqrt();
    let dphi = rng.uniform(-PI, PI);
    let p_star = (0.25 * m * m - MU_MASS * MU_MASS).max(0.0).sqrt();
    let e_star = (p_star * p_star + MU_MASS * MU_MASS).sqrt();
    let dir = [sin_t * dphi.cos(), sin_t * dphi.sin(), cos_t];
    let mu1 = [p_star * dir[0], p_star * dir[1], p_star * dir[2], e_star];
    let mu2 = [-p_star * dir[0], -p_star * dir[1], -p_star * dir[2], e_star];

    let q1 = if rng.bool_with(0.5) { 1 } else { -1 };
    for (p4, q) in [(mu1, q1), (mu2, -q1)] {
        let lab = boost(p4, beta);
        let (mpt, meta_, mphi) = ptetaphi([lab[0], lab[1], lab[2]]);
        // Loose acceptance.
        if mpt > 3.0 && meta_.abs() < 2.4 && out.len() < MAX_MUONS {
            out.push((mpt, meta_, mphi, q));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::kinematics::{inv_mass, p4_from_ptetaphim};

    #[test]
    fn deterministic() {
        let a = generate_drellyan(200, 42);
        let b = generate_drellyan(200, 42);
        assert_eq!(a, b);
        let c = generate_drellyan(200, 43);
        assert_ne!(a, c);
    }

    #[test]
    fn valid_structure_and_bounds() {
        let cs = generate_drellyan(3000, 1);
        cs.validate().unwrap();
        let off = cs.offsets_of("muons").unwrap();
        for w in off.windows(2) {
            assert!((w[1] - w[0]) as usize <= MAX_MUONS);
        }
        for &e in cs.leaf("muons.eta").unwrap().as_f32().unwrap() {
            assert!(e.abs() < 2.4 + 1e-3);
        }
        for &p in cs.leaf("muons.pt").unwrap().as_f32().unwrap() {
            assert!(p > 0.0);
        }
    }

    #[test]
    fn muons_sorted_by_pt_within_event() {
        let cs = generate_drellyan(2000, 2);
        let off = cs.offsets_of("muons").unwrap();
        let pt = cs.leaf("muons.pt").unwrap().as_f32().unwrap();
        for w in off.windows(2) {
            for k in w[0]..w[1] - 1 {
                assert!(pt[k as usize] >= pt[k as usize + 1]);
            }
        }
    }

    #[test]
    fn z_peak_visible_in_dimuon_mass() {
        // Opposite-charge pairs from 2-muon events should peak near 91 GeV.
        let cs = generate_drellyan(20_000, 3);
        let off = cs.offsets_of("muons").unwrap();
        let pt = cs.leaf("muons.pt").unwrap().as_f32().unwrap();
        let eta = cs.leaf("muons.eta").unwrap().as_f32().unwrap();
        let phi = cs.leaf("muons.phi").unwrap().as_f32().unwrap();
        let mut in_peak = 0usize;
        let mut total = 0usize;
        for i in 0..cs.n_events {
            let (lo, hi) = (off[i] as usize, off[i + 1] as usize);
            if hi - lo != 2 {
                continue;
            }
            let a = p4_from_ptetaphim(pt[lo] as f64, eta[lo] as f64, phi[lo] as f64, MU_MASS);
            let b = p4_from_ptetaphim(
                pt[lo + 1] as f64,
                eta[lo + 1] as f64,
                phi[lo + 1] as f64,
                MU_MASS,
            );
            let m = inv_mass(a, b);
            total += 1;
            if (m - Z_MASS).abs() < 10.0 {
                in_peak += 1;
            }
        }
        assert!(total > 5_000, "need a decent number of dimuon events, got {total}");
        assert!(
            in_peak as f64 > 0.5 * total as f64,
            "Z peak not visible: {in_peak}/{total} in ±10 GeV window"
        );
    }

    #[test]
    fn average_multiplicity_reasonable() {
        let cs = generate_drellyan(10_000, 4);
        let total = cs.leaf("muons.pt").unwrap().len();
        let avg = total as f64 / cs.n_events as f64;
        assert!((1.0..3.0).contains(&avg), "avg multiplicity {avg}");
    }
}
