//! Zone-map index subsystem: indexed execution must equal the full scan
//! bit-identically, and the skip counters must prove pruning engages.
//!
//! The guarantees under test:
//!   * `run_indexed` (chunk skip / take-all / scan) equals the unindexed
//!     `run` to the last bit — bins, under/overflow, count AND moments —
//!     across randomized cut shapes (extreme and interior thresholds,
//!     `else` branches, weighted fills) over NaN-laden columns;
//!   * morsel parallelism composes with skipping across the
//!     {1, 7, 1024, whole} × {1, 2, 8} grid;
//!   * the cluster advertises only non-skipped partitions: a
//!     1%-selectivity cut over pt-clustered data skips ≥ 90% of the board
//!     while the merged histogram matches a local full scan bin-exactly;
//!   * the server's `stats` op reports the skip counters and the `warm`
//!     op repopulates the result cache after a dataset re-registration.

use hepq::columnar::arrays::{Array, ColumnSet};
use hepq::columnar::schema::muon_event_schema;
use hepq::coord::{Cluster, ClusterConfig, Policy};
use hepq::datagen::generate_drellyan;
use hepq::engine::{Backend, Query};
use hepq::hist::H1;
use hepq::index::ZoneMap;
use hepq::queryir::{self, lower, predicate, ZoneDecision};
use hepq::server::{Client, Server};
use hepq::util::json::Json;
use hepq::util::propkit::{check, Config, Gen};
use hepq::util::rng::Pcg32;
use std::sync::Arc;

/// A Drell-Yan-like sample whose muons.pt content array is sorted
/// ascending — the clustered layout zone maps exploit. Other columns keep
/// their original (unsorted) values, which is fine: the schemas stay
/// consistent and the cut queries below only constrain pt.
fn pt_sorted_drellyan(n_events: usize, seed: u64) -> (ColumnSet, Vec<f32>) {
    let mut cs = generate_drellyan(n_events, seed);
    let mut pts = cs.leaf("muons.pt").unwrap().as_f32().unwrap().to_vec();
    pts.sort_by(|a, b| a.total_cmp(b));
    cs.leaves.insert("muons.pt".into(), Array::F32(pts.clone()));
    (cs, pts)
}

/// A hand-built muon sample with NaN injected into pt and eta at the
/// given rates — the hostile case for statistics-based skipping.
fn nan_laden_dataset(n_events: usize, seed: u64, nan_rate: f64, sorted: bool) -> ColumnSet {
    let mut rng = Pcg32::new(seed);
    let mut offsets = vec![0i64];
    let mut n_items = 0usize;
    for _ in 0..n_events {
        n_items += rng.below(5) as usize;
        offsets.push(n_items as i64);
    }
    let mut pt: Vec<f32> = (0..n_items)
        .map(|_| {
            if rng.bool_with(nan_rate) {
                f32::NAN
            } else {
                rng.uniform(0.0, 100.0) as f32
            }
        })
        .collect();
    if sorted {
        pt.sort_by(|a, b| a.total_cmp(b));
    }
    let eta: Vec<f32> = (0..n_items)
        .map(|_| {
            if rng.bool_with(nan_rate * 2.0) {
                f32::NAN
            } else {
                rng.uniform(-2.4, 2.4) as f32
            }
        })
        .collect();
    let phi: Vec<f32> = (0..n_items).map(|_| rng.uniform(-3.14, 3.14) as f32).collect();
    let charge: Vec<i32> = (0..n_items)
        .map(|_| if rng.bool_with(0.5) { 1 } else { -1 })
        .collect();
    let met: Vec<f32> = (0..n_events).map(|_| rng.exponential(20.0) as f32).collect();
    let mut cs = ColumnSet::empty(muon_event_schema());
    cs.n_events = n_events;
    cs.offsets.insert("muons".into(), offsets);
    cs.leaves.insert("muons.pt".into(), Array::F32(pt));
    cs.leaves.insert("muons.eta".into(), Array::F32(eta));
    cs.leaves.insert("muons.phi".into(), Array::F32(phi));
    cs.leaves.insert("muons.charge".into(), Array::I32(charge));
    cs.leaves.insert("met".into(), Array::F32(met));
    cs.validate().unwrap();
    cs
}

/// Random fused cut/fill programs: thresholds at extremes (always pass /
/// always fail) and in the interior, nested cuts, `else` branches,
/// NaN-producing values and weighted fills.
fn random_cut_program(g: &mut Gen) -> String {
    const THRESHOLDS: [&str; 6] = ["-10", "0", "25", "60", "99.5", "500"];
    fn fill(g: &mut Gen) -> String {
        const VALUES: [&str; 4] = [
            "muon.pt",
            "sqrt(muon.eta)",
            "muon.pt * 0.5 + muon.eta",
            "abs(muon.eta) * 40",
        ];
        const WEIGHTS: [&str; 3] = ["", ", 0.5", ", 0.25"];
        let v = VALUES[g.usize_to(VALUES.len() - 1)];
        let w = WEIGHTS[g.usize_to(WEIGHTS.len() - 1)];
        format!("fill({v}{w})")
    }
    let thr = THRESHOLDS[g.usize_to(THRESHOLDS.len() - 1)];
    let cond = match g.usize_to(3) {
        0 => format!("muon.pt > {thr}"),
        1 => format!("muon.pt > {thr} and muon.eta < 1.5"),
        2 => format!("sqrt(muon.pt) > 7"),
        _ => format!("not muon.pt > {thr}"),
    };
    match g.usize_to(2) {
        0 => format!(
            "for event in dataset:\n    for muon in event.muons:\n        \
             if {cond}:\n            {}\n",
            fill(g)
        ),
        1 => format!(
            "for event in dataset:\n    for muon in event.muons:\n        \
             if {cond}:\n            {}\n        else:\n            {}\n",
            fill(g),
            fill(g)
        ),
        _ => format!(
            "for event in dataset:\n    for muon in event.muons:\n        \
             if {cond}:\n            if muon.pt < 80:\n                {}\n        {}\n",
            fill(g),
            fill(g)
        ),
    }
}

/// The core acceptance property: indexed execution == full scan to the
/// bit, for arbitrary cut shapes over NaN-laden (and sometimes clustered)
/// data, at multiple binnings.
#[test]
fn prop_indexed_execution_equals_full_scan_bit_identically() {
    let cfg = Config {
        cases: 24,
        ..Config::default()
    };
    check(
        "indexed-equals-full-scan",
        &cfg,
        |g| {
            (
                random_cut_program(g),
                1 + g.usize_to(2_000),
                g.rng.next_u64(),
                g.usize_to(1) == 1, // sorted?
            )
        },
        |(src, n, seed, sorted)| {
            let cs = nan_laden_dataset(*n, *seed, 0.15, *sorted);
            let zm = ZoneMap::build(&cs);
            let prog = queryir::compile(src, &cs.schema)?;
            let cp = lower::lower(&prog)?;
            for (n_bins, lo, hi) in [(64, -8.0, 120.0), (9, 3.0, 40.0)] {
                let mut full = H1::new(n_bins, lo, hi);
                lower::run(&cp, &cs, &mut full)?;
                let mut indexed = H1::new(n_bins, lo, hi);
                lower::run_indexed(&cp, &cs, Some(&zm), &mut indexed)?;
                if indexed != full {
                    return Err(format!(
                        "indexed != full scan on {n_bins}x[{lo},{hi}) for:\n{src}"
                    ));
                }
            }
            Ok(())
        },
    );
}

fn assert_morsel_equiv(seq: &H1, par: &H1, what: &str) {
    assert_eq!(seq.bins, par.bins, "{what}: bins");
    assert_eq!(seq.underflow, par.underflow, "{what}: underflow");
    assert_eq!(seq.overflow, par.overflow, "{what}: overflow");
    assert_eq!(seq.count, par.count, "{what}: count");
    for (name, a, b) in [("sum", seq.sum, par.sum), ("sum2", seq.sum2, par.sum2)] {
        let tol = 1e-9 * a.abs().max(b.abs()).max(1.0);
        assert!(
            (a - b).abs() <= tol,
            "{what}: {name} {a} vs {b} beyond merge tolerance"
        );
    }
}

/// The ISSUE grid with skipping enabled: morsel sizes {1, 7, 1024, whole}
/// × thread counts {1, 2, 8} over a pt-clustered sample with an interior
/// cut (so skip, take-all and scan chunks all occur).
#[test]
fn morsel_grid_with_skipping_matches_sequential() {
    const N: usize = 5_000;
    let (cs, pts) = pt_sorted_drellyan(N, 71);
    let thr = pts[pts.len() / 2] as f64;
    let zm = ZoneMap::build(&cs);
    let src = format!(
        "for event in dataset:\n    for muon in event.muons:\n        \
         if muon.pt > {thr}:\n            fill(muon.pt)\n        \
         fill(muon.eta, 0.5)\n"
    );
    let prog = queryir::compile(&src, &cs.schema).unwrap();
    let cp = lower::lower(&prog).unwrap();
    let mut seq = H1::new(64, -4.0, 128.0);
    lower::run(&cp, &cs, &mut seq).unwrap();
    let mut total_pruned = 0u64;
    for morsel_events in [1usize, 7, 1024, N] {
        for threads in [1usize, 2, 8] {
            let cfg = lower::ParallelCfg {
                threads,
                morsel_events,
            };
            let mut par = H1::new(64, -4.0, 128.0);
            let rep = lower::run_parallel_indexed(&cp, &cs, Some(&zm), &mut par, cfg).unwrap();
            assert_morsel_equiv(
                &seq,
                &par,
                &format!("skip morsel={morsel_events} threads={threads}"),
            );
            total_pruned += rep.chunks_pruned();
        }
    }
    // The unconditional eta fill keeps chunks from skipping entirely, but
    // the pt cut must still prune (take-all) on the clustered layout.
    assert!(total_pruned > 0, "no pruning engaged across the whole grid");
}

/// Cut-only bodies on clustered data skip chunks outright, morsels
/// included, and report it.
#[test]
fn clustered_cut_skips_chunks_under_morsels() {
    const N: usize = 6_000;
    let (cs, pts) = pt_sorted_drellyan(N, 72);
    let thr = pts[pts.len() - 1 - pts.len() / 100] as f64;
    let zm = ZoneMap::build(&cs);
    let src = format!(
        "for event in dataset:\n    for muon in event.muons:\n        \
         if muon.pt > {thr}:\n            fill(muon.pt)\n"
    );
    let prog = queryir::compile(&src, &cs.schema).unwrap();
    let cp = lower::lower(&prog).unwrap();
    let mut seq = H1::new(64, 0.0, 128.0);
    lower::run(&cp, &cs, &mut seq).unwrap();
    let cfg = lower::ParallelCfg {
        threads: 4,
        morsel_events: 512,
    };
    let mut par = H1::new(64, 0.0, 128.0);
    let rep = lower::run_parallel_indexed(&cp, &cs, Some(&zm), &mut par, cfg).unwrap();
    assert_eq!(seq.bins, par.bins);
    assert_eq!(seq.count, par.count);
    assert!(rep.chunks_skipped > 0, "{rep:?}");
    assert!(
        rep.chunks_skipped >= 4 * rep.chunks_scanned,
        "a ~1% cut should skip most chunk work: {rep:?}"
    );
}

fn pruning_cluster(events: usize, seed: u64, part_events: usize) -> (Cluster, ColumnSet) {
    let (cs, _) = pt_sorted_drellyan(events, seed);
    let cluster = Cluster::start(
        ClusterConfig {
            n_workers: 3,
            cache_bytes_per_worker: 64 << 20,
            policy: Policy::AnyPull,
            fetch_delay_per_mib: std::time::Duration::ZERO,
            claim_ttl: std::time::Duration::from_secs(10),
            ..ClusterConfig::default()
        },
        Backend::compiled(),
    );
    cluster.catalog.register("dy", cs.clone(), part_events);
    (cluster, cs)
}

/// The ISSUE acceptance criterion: a 1%-selectivity cut skips ≥ 90% of
/// partitions (counters asserted) and the merged histogram is
/// bin-identical to a local unindexed full scan.
#[test]
fn cluster_skips_90pct_of_partitions_at_1pct_selectivity() {
    let (cluster, cs) = pruning_cluster(20_000, 77, 500);
    let n_parts = cluster.catalog.n_partitions("dy").unwrap();
    assert_eq!(n_parts, 40);
    let mut pts = cs.leaf("muons.pt").unwrap().as_f32().unwrap().to_vec();
    pts.sort_by(|a, b| a.total_cmp(b));
    let thr = pts[pts.len() - 1 - pts.len() / 100] as f64;
    let src = format!(
        "for event in dataset:\n    for muon in event.muons:\n        \
         if muon.pt > {thr}:\n            fill(muon.pt)\n"
    );
    let q = Query::from_source(src.clone(), "dy").with_binning(64, 0.0, 128.0);
    let res = cluster.run(&q).unwrap();

    // ≥ 90% of the board never existed.
    assert!(
        res.skipped * 10 >= n_parts * 9,
        "skipped {}/{} partitions",
        res.skipped,
        n_parts
    );
    assert_eq!(res.skipped + res.partitions, n_parts);
    let (skipped, scanned) = cluster.partition_skip_stats();
    assert_eq!(skipped as usize, res.skipped);
    assert_eq!(scanned as usize, res.partitions);
    // The per-query chunk counters aggregated from the workers' indexed
    // runs cover the surviving partitions' chunks.
    let c = &res.chunks;
    assert!(
        c.chunks_skipped + c.chunks_take_all + c.chunks_scanned > 0,
        "per-query chunk counters should be populated: {c:?}"
    );

    // Bit-identical to the local unindexed scan (weight-1 fills: bins and
    // count are integers, exact under any merge order).
    let prog = queryir::compile(&src, &cs.schema).unwrap();
    let cp = lower::lower(&prog).unwrap();
    let mut local = H1::new(64, 0.0, 128.0);
    lower::run(&cp, &cs, &mut local).unwrap();
    assert_eq!(res.hist.bins, local.bins);
    assert_eq!(res.hist.count, local.count);
    assert!(res.hist.total() > 0.0, "the surviving 1% still fills");
    cluster.shutdown();
}

/// Partition pruning decisions agree with a direct predicate evaluation,
/// and an unprunable query skips nothing.
#[test]
fn cluster_pruning_is_sound_and_conservative() {
    let (cluster, cs) = pruning_cluster(8_000, 78, 500);
    // Unprunable (per-event state): everything scans.
    let q = Query::new(hepq::engine::QueryKind::MaxPt, "dy", "muons");
    let res = cluster.run(&q).unwrap();
    assert_eq!(res.skipped, 0);
    assert_eq!(res.partitions, 16);
    assert_eq!(res.events, 8_000);

    // An always-false cut skips every partition: the result is the empty
    // histogram, exactly like a full scan would produce.
    let src = "\
for event in dataset:
    for muon in event.muons:
        if muon.pt > 100000:
            fill(muon.pt)
";
    let q = Query::from_source(src, "dy").with_binning(32, 0.0, 128.0);
    let res = cluster.run(&q).unwrap();
    assert_eq!(res.skipped, 16);
    assert_eq!(res.partitions, 0);
    assert_eq!(res.hist.total(), 0.0);

    // The submit-time verdicts match classify_partition on the catalog's
    // own zone maps.
    let prog = queryir::compile(src, &cs.schema).unwrap();
    let pred = predicate::extract(&prog).unwrap();
    for zm in cluster.catalog.partition_zone_maps("dy").unwrap() {
        assert_eq!(pred.classify_partition(&zm), ZoneDecision::Skip);
    }
    cluster.shutdown();
}

// ----------------------------------------------------------- server tests

type ServeHandle = std::thread::JoinHandle<Result<std::net::SocketAddr, String>>;

/// Send one raw op line and unwrap the response.
fn op(client: &mut Client, raw: &str) -> Json {
    client.request(&Json::parse(raw).unwrap()).unwrap()
}

fn start_server(cluster: Arc<Cluster>) -> (Client, ServeHandle) {
    let port = {
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap().port()
    };
    let addr = format!("127.0.0.1:{port}");
    let server = Server::new(cluster);
    let addr2 = addr.clone();
    let t = std::thread::spawn(move || server.serve(&addr2));
    let mut client = None;
    for _ in 0..200 {
        match Client::connect(&addr) {
            Ok(c) => {
                client = Some(c);
                break;
            }
            Err(_) => std::thread::sleep(std::time::Duration::from_millis(10)),
        }
    }
    (client.expect("connect to server"), t)
}

/// One stats block carries the whole data-skipping story, and `warm`
/// repopulates the result cache after a re-registration.
#[test]
fn server_stats_report_skipping_and_warm_repopulates_cache() {
    let (cluster, cs) = pruning_cluster(12_000, 79, 1_000);
    let cluster = Arc::new(cluster);
    let (mut client, t) = start_server(cluster.clone());

    let mut pts = cs.leaf("muons.pt").unwrap().as_f32().unwrap().to_vec();
    pts.sort_by(|a, b| a.total_cmp(b));
    let thr = pts[pts.len() - 1 - pts.len() / 100] as f64;
    let src = format!(
        "for event in dataset:\n    for muon in event.muons:\n        \
         if muon.pt > {thr}:\n            fill(muon.pt)\n"
    );
    let q = Query::from_source(src, "dy").with_binning(64, 0.0, 128.0);
    let cold = client.query(&q, |_, _| {}).unwrap();
    assert_eq!(cold.get("ok"), Some(&Json::Bool(true)));
    let skipped = cold.get("skipped").and_then(|v| v.as_usize()).unwrap();
    assert!(skipped > 0, "{cold}");

    let stats = op(&mut client, r#"{"op":"stats"}"#);
    let ds = stats.get("data_skipping").expect("data_skipping block");
    let p_skip = ds.get("partitions_skipped").and_then(|v| v.as_usize());
    assert_eq!(p_skip, Some(skipped), "{stats}");
    assert!(ds.get("chunks_skipped").is_some());
    assert!(ds.get("chunks_take_all").is_some());
    assert_eq!(ds.get("result_cache_warms").and_then(|v| v.as_u64()), Some(0));
    let workers = ds.get("workers").and_then(|v| v.as_arr()).unwrap();
    assert_eq!(workers.len(), 3);
    assert!(workers[0].get("partition_cache_hit_rate").is_some());

    // Re-register (version bump kills the cache), then warm: the stored
    // query re-runs and the next ask is a cache hit again.
    cluster.catalog.register("dy", cs.clone(), 1_000);
    let warm = op(&mut client, r#"{"op":"warm","dataset":"dy"}"#);
    assert_eq!(warm.get("ok"), Some(&Json::Bool(true)), "{warm}");
    assert_eq!(warm.get("warmed").and_then(|v| v.as_u64()), Some(1));

    let hot = client.query(&q, |_, _| {}).unwrap();
    assert_eq!(hot.get("cached"), Some(&Json::Bool(true)), "{hot}");
    let h_cold = H1::from_json(cold.get("hist").unwrap()).unwrap();
    let h_hot = H1::from_json(hot.get("hist").unwrap()).unwrap();
    assert_eq!(h_hot, h_cold);

    let stats = op(&mut client, r#"{"op":"stats"}"#);
    let ds = stats.get("data_skipping").expect("data_skipping block");
    assert_eq!(ds.get("result_cache_warms").and_then(|v| v.as_u64()), Some(1));

    // Warming an unknown dataset is an error, not a crash.
    let bad = op(&mut client, r#"{"op":"warm","dataset":"nope"}"#);
    assert_eq!(bad.get("ok"), Some(&Json::Bool(false)));

    client.shutdown_server().unwrap();
    let _ = t.join().unwrap();
}
