//! Golden-file lockdown for the AGC statement set: fixed queries over a
//! fixed tt̄ dataset, every output float pinned by its exact `f64::to_bits`
//! pattern in `rust/tests/golden/agc_*.json`.
//!
//! Workflow:
//! - Normal runs compare the freshly computed result against the checked-in
//!   golden file, bit for bit, and name the first drifted line on failure.
//! - `HEPQ_BLESS=1 cargo test --test test_agc_golden` regenerates the
//!   files after an *intentional* numeric change (review the diff!).
//! - A missing file bootstraps itself: the result is computed twice from
//!   scratch (reproducibility check), written, and the test passes — so a
//!   fresh platform can mint its baseline before locking against it.
//!
//! The golden queries stick to `+ - * / sqrt` and comparisons — IEEE-754
//! exactly-rounded operations — so the bit patterns are portable across
//! conforming platforms. `cos`/`cosh` (libm, implementation-defined last
//! ulps) are deliberately absent here; tier-equivalence tests cover them.

use hepq::columnar::ColumnSet;
use hepq::datagen::generate_ttbar;
use hepq::hist::{Hist, Sink, H1};
use hepq::queryir::{self, flat, lower};
use hepq::util::json::Json;
use std::path::Path;

struct Case {
    name: &'static str,
    src: &'static str,
    x: (usize, f64, f64),
    y: (usize, f64, f64),
}

fn cases() -> Vec<Case> {
    vec![
        Case {
            name: "pairs",
            src: "\
for event in dataset:
    nm = len(event.muons)
    nj = len(event.jets)
    for i in range(nm):
        for j in range(nj):
            m = event.muons[i]
            jet = event.jets[j]
            if jet.pt > 30:
                fill(m.pt + jet.pt)
                fill2(m.pt, jet.pt)
",
            x: (48, 0.0, 512.0),
            y: (24, 0.0, 384.0),
        },
        Case {
            name: "gather",
            src: "\
for event in dataset:
    n = len(event.muons)
    if n > 0:
        fill(event.muons[n - 1].pt)
        fill2(event.muons[0].pt, event.muons[n - 1].pt)
        profile(event.muons[0].pt, n)
",
            x: (64, 0.0, 128.0),
            y: (32, 0.0, 128.0),
        },
        Case {
            name: "vars",
            src: "\
for event in dataset:
    for muon in event.muons:
        if muon.pt > 24:
            fill(muon.pt)
            fill_vars(muon.pt, 0.5, 0.25, 1.0, 2.0, 0.75, 1.5, 4.0, 1.25)
",
            x: (64, 0.0, 128.0),
            y: (8, 0.0, 1.0),
        },
        Case {
            name: "ht",
            src: "\
for event in dataset:
    ht = 0.0
    nj = 0
    for jet in event.jets:
        if jet.pt > 35:
            ht = ht + jet.pt
            nj = nj + 1
    if nj > 1:
        fill(ht)
        profile(ht, nj)
        fill2(ht, nj)
",
            x: (60, 0.0, 1200.0),
            y: (10, 0.0, 10.0),
        },
    ]
}

fn hex(v: f64) -> Json {
    Json::str(format!("{:016x}", v.to_bits()))
}

fn hex_arr(vs: &[f64]) -> Json {
    Json::Arr(vs.iter().map(|v| hex(*v)).collect())
}

fn enc_h1(h: &H1) -> Json {
    Json::obj(vec![
        ("lo", hex(h.lo)),
        ("hi", hex(h.hi)),
        ("bins", hex_arr(&h.bins)),
        ("underflow", hex(h.underflow)),
        ("overflow", hex(h.overflow)),
        ("count", hex(h.count)),
        ("sum", hex(h.sum)),
        ("sum2", hex(h.sum2)),
    ])
}

fn enc_sink(s: &Sink) -> Json {
    let body = match &s.hist {
        Hist::H1(h) => enc_h1(h),
        Hist::H2(h) => Json::obj(vec![
            ("nx", Json::num(h.nx as f64)),
            ("xlo", hex(h.xlo)),
            ("xhi", hex(h.xhi)),
            ("ny", Json::num(h.ny as f64)),
            ("ylo", hex(h.ylo)),
            ("yhi", hex(h.yhi)),
            ("bins", hex_arr(&h.bins)),
            ("out", hex(h.out)),
            ("count", hex(h.count)),
            ("sumx", hex(h.sumx)),
            ("sumx2", hex(h.sumx2)),
            ("sumy", hex(h.sumy)),
            ("sumy2", hex(h.sumy2)),
        ]),
        Hist::Profile(p) => Json::obj(vec![
            ("lo", hex(p.lo)),
            ("hi", hex(p.hi)),
            ("count", hex_arr(&p.count)),
            ("sumy", hex_arr(&p.sumy)),
            ("sumy2", hex_arr(&p.sumy2)),
            ("under", hex(p.under)),
            ("over", hex(p.over)),
            ("total", hex(p.total)),
        ]),
    };
    Json::obj(vec![
        ("label", Json::str(s.label.clone())),
        ("type", Json::str(s.hist.type_name())),
        ("hist", body),
    ])
}

/// Compute one case through the flat walker AND the chunked kernels
/// (bit-identity cross-check), then render the canonical golden text.
fn compute(case: &Case, cs: &ColumnSet) -> String {
    let prog = queryir::compile(case.src, &cs.schema).expect(case.name);
    let (x, y) = (case.x, case.y);
    let mut hf = H1::new(x.0, x.1, x.2);
    let mut af = prog.make_aux(x, y);
    flat::run_group(&prog, cs, &mut hf, &mut af).expect(case.name);

    let cp = lower::lower(&prog).expect(case.name);
    let mut hc = H1::new(x.0, x.1, x.2);
    let mut ac = cp.make_aux(x, y);
    lower::run_group(&cp, cs, &mut hc, &mut ac).expect(case.name);
    assert_eq!(hf, hc, "{}: chunked kernels drifted from the flat walker", case.name);
    assert_eq!(af, ac, "{}: chunked aux drifted from the flat walker", case.name);

    let j = Json::obj(vec![
        ("case", Json::str(case.name)),
        ("events", Json::num(EVENTS as f64)),
        ("seed", Json::num(SEED as f64)),
        ("source", Json::str(case.src)),
        ("primary", enc_h1(&hf)),
        ("aux", Json::Arr(af.iter().map(enc_sink).collect())),
    ]);
    format!("{j}\n")
}

/// Name the first divergence instead of dumping two full JSON blobs.
fn first_diff(got: &str, want: &str) -> String {
    let (g, w) = (got.as_bytes(), want.as_bytes());
    let at = g.iter().zip(w).take_while(|(a, b)| a == b).count();
    let lo = at.saturating_sub(40);
    let ctx = |s: &[u8]| String::from_utf8_lossy(&s[lo..(at + 40).min(s.len())]).into_owned();
    format!("first divergence at byte {at}:\n  got  …{}…\n  want …{}…", ctx(g), ctx(w))
}

const EVENTS: usize = 3_000;
const SEED: u64 = 77;

#[test]
fn golden_files_lock_down_agc_results() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/tests/golden");
    std::fs::create_dir_all(&dir).unwrap();
    let bless = std::env::var("HEPQ_BLESS").map(|v| v == "1").unwrap_or(false);
    let cs = generate_ttbar(EVENTS, 6, SEED);
    for case in cases() {
        let got = compute(&case, &cs);
        // Run-to-run reproducibility from a fresh compile, before anything
        // is compared or written: a nondeterministic result must never
        // become a baseline.
        let again = compute(&case, &cs);
        assert_eq!(got, again, "case {}: result is not run-to-run reproducible", case.name);

        let path = dir.join(format!("agc_{}.json", case.name));
        if bless || !path.exists() {
            std::fs::write(&path, &got).unwrap();
            eprintln!("blessed {}", path.display());
            continue;
        }
        let want = std::fs::read_to_string(&path).unwrap();
        assert!(
            got == want,
            "case {}: output drifted from {}\n{}\nIf the change is intentional, \
             regenerate with `HEPQ_BLESS=1 cargo test --test test_agc_golden` \
             and review the diff.",
            case.name,
            path.display(),
            first_diff(&got, &want)
        );
    }
}
