//! Storage-fault grid — the chaos suite for the end-to-end integrity story.
//!
//! Every test drives an injected failure mode (bit-flip, truncation, EIO,
//! short read, latency — per basket, per codec, per fetch site) through the
//! public read paths and asserts the only two acceptable outcomes:
//!
//!   1. a **bit-exact** result, when retry/failover can absorb the fault, or
//!   2. a **structured, typed error** (or explicit partial manifest),
//!
//! never a panic and never silently wrong data. Bit-flip positions honor
//! `HEPQ_FAULT_SEED` (pinned in the CI chaos job, default 0xC0FFEE) so a
//! failing grid cell reproduces locally with the same seed.

use hepq::coord::{Cluster, ClusterConfig, ClusterError, Policy};
use hepq::datagen::generate_drellyan;
use hepq::engine::{Backend, Query, QueryKind};
use hepq::format::{
    fault, write_dataset, Codec, DatasetReader, FaultKind, FaultRule, FormatError, WriteOptions,
};
use std::path::PathBuf;
use std::time::Duration;

fn seed() -> u64 {
    std::env::var("HEPQ_FAULT_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE)
}

fn tmpfile(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("hepq-fault-grid");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

/// A small cluster tuned for fault tests: no simulated fetch delay, short
/// claim TTL, default (k=2) replication.
fn fault_cluster() -> Cluster {
    Cluster::start(
        ClusterConfig {
            n_workers: 2,
            cache_bytes_per_worker: 64 << 20,
            policy: Policy::AnyPull,
            fetch_delay_per_mib: Duration::ZERO,
            claim_ttl: Duration::from_secs(10),
            ..ClusterConfig::default()
        },
        Backend::compiled(),
    )
}

/// Flip one seeded bit in **every basket of every branch**, one at a time,
/// under both codecs: each cell of the grid must surface as a typed
/// `Corrupt` error naming the damaged branch — the read never "succeeds" —
/// and once the fault rule is gone the same file reads back bit-exact.
#[test]
fn bitflip_grid_every_basket_every_codec() {
    for codec in [Codec::None, Codec::Zstd(2)] {
        let cs = generate_drellyan(1_500, 21);
        let path = tmpfile(&format!("grid_flip_{}.froot", codec.name()));
        let opts = WriteOptions { codec, basket_items: 256, ..WriteOptions::default() };
        write_dataset(&path, &cs, opts).unwrap();
        let mut r = DatasetReader::open(&path).unwrap();
        let reference = r.read_full().unwrap();
        let branches: Vec<(String, usize)> =
            r.header.branches.iter().map(|b| (b.name.clone(), b.baskets.len())).collect();
        drop(r);
        let total: usize = branches.iter().map(|(_, n)| n).sum();
        assert!(total >= 8, "grid needs multiple baskets, got {total}");
        for (branch, n_baskets) in &branches {
            for idx in 0..*n_baskets {
                let h = fault::inject(FaultRule::new(
                    format!("basket:{}:{branch}:{idx}", path.display()),
                    FaultKind::BitFlip { seed: seed() ^ idx as u64 },
                    1,
                ));
                let mut r = DatasetReader::open(&path).unwrap();
                let err = match r.read_full() {
                    Ok(_) => panic!("flipped bit in {branch}[{idx}] must not read clean"),
                    Err(e) => e,
                };
                assert!(
                    matches!(err, FormatError::Corrupt { .. }),
                    "{branch}[{idx}]: want Corrupt, got {err}"
                );
                assert!(!err.is_transient(), "corruption is permanent: {err}");
                assert!(err.to_string().contains(branch.as_str()), "{branch}[{idx}]: {err}");
                assert_eq!(h.fired(), 1, "{branch}[{idx}]: rule must have fired");
                drop(h);
                assert_eq!(r.read_full().unwrap(), reference, "{branch}[{idx}]: clean reread");
            }
        }
    }
}

/// Chop the file at a spread of byte positions — inside the magic, the
/// preamble, the basket region, and the trailing header — and assert every
/// cut is a typed error at open or read time, never a panic and never a
/// quietly wrong ColumnSet.
#[test]
fn truncation_grid_is_typed_never_panics() {
    let cs = generate_drellyan(800, 22);
    let path = tmpfile("grid_trunc.froot");
    write_dataset(&path, &cs, WriteOptions::default()).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    let reference = DatasetReader::open(&path).unwrap().read_full().unwrap();
    let header_pos = u64::from_le_bytes(bytes[8..16].try_into().unwrap()) as usize;
    let len = bytes.len();
    let cuts = [
        0,
        1,
        7, // mid-magic
        8,
        15, // mid header_pos
        16,
        27, // mid preamble CRC
        28,
        28 + (header_pos - 28) / 2, // mid-baskets
        header_pos - 1,
        header_pos + 1, // mid-header
        len - 10,
        len - 1,
        len, // untouched control
    ];
    for cut in cuts {
        let p = tmpfile(&format!("grid_trunc_{cut}.froot"));
        std::fs::write(&p, &bytes[..cut]).unwrap();
        let outcome = DatasetReader::open(&p).and_then(|mut r| r.read_full());
        match outcome {
            Err(err) => {
                assert!(cut < len, "untouched file must read: {err}");
                // Exercising Display is part of the contract: rendering the
                // error must not panic either.
                assert!(!err.to_string().is_empty());
            }
            Ok(got) => {
                assert_eq!(cut, len, "cut at {cut}/{len} bytes read back \"clean\"");
                assert_eq!(got, reference);
            }
        }
    }
}

/// Transient EIO: the read fails typed-transient, and the immediate retry
/// (rule spent) returns the exact bytes — the contract the catalog's retry
/// loop is built on. Runs under both codecs.
#[test]
fn transient_eio_retry_reads_bit_exact() {
    for codec in [Codec::None, Codec::Flate] {
        let cs = generate_drellyan(1_200, 23);
        let path = tmpfile(&format!("grid_eio_{}.froot", codec.name()));
        let opts = WriteOptions { codec, basket_items: 300, ..WriteOptions::default() };
        write_dataset(&path, &cs, opts).unwrap();
        let want = cs.leaf("muons.pt").unwrap().as_f32().unwrap().to_vec();
        let h = fault::inject(FaultRule::new(
            format!("basket:{}:muons.pt", path.display()),
            FaultKind::Eio,
            1,
        ));
        let mut r = DatasetReader::open(&path).unwrap();
        let err = r.read_leaf("muons.pt").unwrap_err();
        assert!(err.is_transient(), "EIO must be transient: {err}");
        let again = r.read_leaf("muons.pt").unwrap();
        assert_eq!(again.as_f32().unwrap(), &want[..], "retry must be bit-exact");
        assert_eq!(h.fired(), 1);
    }
}

/// Short reads and in-flight truncations (0 bytes kept, a few bytes kept):
/// all typed, all permanent, and the file itself stays readable once the
/// fault clears.
#[test]
fn shortread_and_inflight_truncation_are_typed() {
    let cs = generate_drellyan(900, 24);
    let path = tmpfile("grid_short.froot");
    write_dataset(&path, &cs, WriteOptions::default()).unwrap();
    for kind in [
        FaultKind::ShortRead,
        FaultKind::Truncate { keep: 0 },
        FaultKind::Truncate { keep: 9 },
    ] {
        let h = fault::inject(FaultRule::new(
            format!("basket:{}:muons.phi", path.display()),
            kind.clone(),
            1,
        ));
        let mut r = DatasetReader::open(&path).unwrap();
        let err = r.read_leaf("muons.phi").expect_err("damaged read must not pass");
        assert!(!err.is_transient(), "{kind:?} must be permanent: {err}");
        assert_eq!(h.fired(), 1, "{kind:?}");
        drop(h);
        assert!(r.read_leaf("muons.phi").is_ok(), "clean reread after {kind:?}");
    }
}

/// A mixed storm at the catalog fetch seam — transient EIOs on one
/// partition, a permanently corrupt replica on another, injected latency on
/// a third — must be fully absorbed by retry + quarantine + failover: the
/// query result is bit-exact and reports zero failed partitions.
#[test]
fn cluster_absorbs_mixed_fault_storm_bit_exact() {
    let cs = generate_drellyan(10_000, 33);
    let q = Query::new(QueryKind::MassPairs, "dy_storm", "muons");
    let make = || {
        let c = fault_cluster();
        c.catalog.register("dy_storm", cs.clone(), 1_000);
        c
    };
    let clean = make();
    let want = clean.run(&q).unwrap();
    clean.shutdown();

    let c = make();
    let _h = fault::inject_all(vec![
        FaultRule::new("fetch:dy_storm:part0", FaultKind::Eio, 2),
        FaultRule::new("fetch:dy_storm:part2:replica0", FaultKind::Corrupt, 1_000),
        FaultRule::new("fetch:dy_storm:part4", FaultKind::Latency { ms: 2 }, 4),
    ]);
    let got = c.run(&q).unwrap();
    assert_eq!(got.hist, want.hist, "storm-absorbed result must be bit-exact");
    assert!(got.failed.is_empty(), "no partition may fail: {:?}", got.failed);
    assert!(c.catalog.read_retries() >= 1, "EIOs should have been retried");
    assert!(c.catalog.corruption_detected() >= 1);
    assert_eq!(
        c.catalog.quarantined(),
        vec![("dy_storm".to_string(), 1, 2, 0)],
        "exactly the corrupt replica is quarantined"
    );
    c.shutdown();
}

/// When **every** replica of a partition is corrupt, the strict query fails
/// with the structured `PartitionsFailed` error and the `allow_partial`
/// rerun degrades: merged histogram over the readable partitions plus a
/// per-partition error manifest.
#[test]
fn cluster_unreadable_partition_degrades_with_manifest() {
    let cs = generate_drellyan(6_000, 34);
    let c = fault_cluster();
    c.catalog.register("dy_manifest", cs.clone(), 1_000);
    // Trailing colon: "part1:" cannot accidentally match a part1x tag.
    let _h = fault::inject(FaultRule::new(
        "fetch:dy_manifest:part1:",
        FaultKind::Corrupt,
        1_000,
    ));
    let q = Query::new(QueryKind::FlatHist, "dy_manifest", "muons");
    match c.run(&q) {
        Err(ClusterError::PartitionsFailed { failed, .. }) => {
            assert_eq!(failed.len(), 1);
            assert_eq!(failed[0].0, 1);
        }
        Err(other) => panic!("expected PartitionsFailed, got {other}"),
        Ok(_) => panic!("strict query over an unreadable partition must fail"),
    }
    let res = c.run(&q.clone().with_allow_partial(true)).unwrap();
    assert_eq!(res.failed.len(), 1, "manifest lists the dead partition");
    assert_eq!(res.failed[0].0, 1);
    // The degraded histogram is exactly the readable partitions' merge.
    let mut want = hepq::hist::H1::new(q.n_bins, q.lo, q.hi);
    for (p, part) in cs.partition(1_000).iter().enumerate() {
        if p == 1 {
            continue;
        }
        let mut h = hepq::hist::H1::new(q.n_bins, q.lo, q.hi);
        hepq::engine::columnar_exec::run(q.kind, part, "muons", &mut h).unwrap();
        want.merge(&h).unwrap();
    }
    assert_eq!(res.hist.bins, want.bins);
    assert_eq!(res.hist.count, want.count);
    c.shutdown();
}

/// The checked-in corrupt-file corpus: structurally broken files a writer
/// crash, a bad disk, or a future format could leave behind. Every one must
/// open to a typed error — this pins the error taxonomy across releases.
#[test]
fn corrupt_corpus_every_file_is_a_typed_error() {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/corpus");
    let open_err = |name: &str| DatasetReader::open(&dir.join(name)).unwrap_err();

    assert_eq!(open_err("bad_magic.froot"), FormatError::BadMagic);
    assert_eq!(
        open_err("future_version.froot"),
        FormatError::UnsupportedVersion { version: 9 }
    );
    let e = open_err("unfinalized.froot");
    assert!(matches!(e, FormatError::Corrupt { .. }), "got {e}");
    assert!(e.to_string().contains("not finalized"), "{e}");
    let e = open_err("header_past_eof.froot");
    assert!(matches!(e, FormatError::Truncated { .. }), "got {e}");
    let e = open_err("truncated_preamble.froot");
    assert!(matches!(e, FormatError::Truncated { .. }), "got {e}");

    // Belt and braces: every corpus file — including ones a future session
    // adds — must fail to open with a typed error, never a panic.
    let mut seen = 0;
    for entry in std::fs::read_dir(&dir).unwrap() {
        let p = entry.unwrap().path();
        if p.extension().and_then(|e| e.to_str()) != Some("froot") {
            continue;
        }
        seen += 1;
        let err = DatasetReader::open(&p)
            .err()
            .unwrap_or_else(|| panic!("{} opened clean", p.display()));
        assert!(!err.to_string().is_empty());
    }
    assert!(seen >= 5, "corpus went missing ({seen} files)");
}
