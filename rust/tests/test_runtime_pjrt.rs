//! Integration: AOT artifacts executed through PJRT must agree with the
//! hand-written columnar executor on real synthetic physics data — the
//! end-to-end proof that L1 (Pallas), L2 (JAX graph) and L3 (Rust) compose.
//!
//! Requires `make artifacts` (skips with a message if missing).

use hepq::datagen::generate_drellyan;
use hepq::engine::{Backend, Query, QueryKind};
use hepq::hist::H1;
use hepq::engine::executor::PjrtBackend;
use std::path::Path;

fn backend() -> Option<PjrtBackend> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts (run `make artifacts`)");
        return None;
    }
    Some(PjrtBackend::new(dir))
}

fn registry_shape() -> Option<usize> {
    let be = backend()?;
    Some(be.registry().expect("registry").shape().n_events)
}


#[test]
fn pjrt_matches_columnar_on_all_queries() {
    let Some(be) = backend() else { return };
    // One artifact-sized partition of real DY events.
    let n = registry_shape().unwrap().min(16384);
    let cs = generate_drellyan(n, 77);
    let pjrt = Backend::Pjrt(be);
    for kind in QueryKind::ALL {
        let q = Query::new(kind, "dy", "muons");
        let mut h_col = H1::new(64, q.lo, q.hi);
        Backend::Columnar.run(&q, &cs, &mut h_col).unwrap();
        let mut h_pjrt = H1::new(64, q.lo, q.hi);
        pjrt.run(&q, &cs, &mut h_pjrt).unwrap();

        assert_eq!(
            h_pjrt.total(),
            h_col.total(),
            "{kind:?}: total fills differ (pjrt {} vs columnar {})",
            h_pjrt.total(),
            h_col.total()
        );
        // f32 (kernel) vs f64 (rust) transcendentals can migrate a value
        // across a bin edge for the pair-mass query; totals are exact and
        // bin-level differences must be tiny.
        let diff: f64 = h_pjrt
            .bins
            .iter()
            .zip(&h_col.bins)
            .map(|(a, b)| (a - b).abs())
            .sum();
        let tol = if kind == QueryKind::MassPairs { 6.0 } else { 0.0 };
        assert!(diff <= tol, "{kind:?}: bins differ by {diff}");
    }
}

#[test]
fn pjrt_chunks_large_datasets() {
    let Some(be) = backend() else { return };
    // 2.5 partitions worth of events exercises the chunking path.
    let n = registry_shape().unwrap() * 5 / 2;
    let cs = generate_drellyan(n, 78);
    let q = Query::new(QueryKind::MaxPt, "dy", "muons");
    let mut h_col = H1::new(64, q.lo, q.hi);
    Backend::Columnar.run(&q, &cs, &mut h_col).unwrap();
    let mut h_pjrt = H1::new(64, q.lo, q.hi);
    Backend::Pjrt(be).run(&q, &cs, &mut h_pjrt).unwrap();
    assert_eq!(h_pjrt.bins, h_col.bins);
    assert_eq!(h_pjrt.total(), h_col.total());
}

#[test]
fn pjrt_empty_partition_is_zero() {
    let Some(be) = backend() else { return };
    let cs = generate_drellyan(0, 1);
    let q = Query::new(QueryKind::PtSumPairs, "dy", "muons");
    let mut h = H1::new(64, q.lo, q.hi);
    Backend::Pjrt(be).run(&q, &cs, &mut h).unwrap();
    assert_eq!(h.total(), 0.0);
}
