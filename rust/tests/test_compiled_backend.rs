//! Equivalence and caching properties of the compiled-tape backend.
//!
//! Core guarantee: for every Table-3 query and randomized event samples,
//! the object interpreter, the AST-walking flat evaluator, the tape VM and
//! the compiled closure graph produce *bit-identical* histograms, and all
//! of them agree with the hand-written columnar loops up to the documented
//! f32-vs-f64 bin-edge tolerance.

use hepq::coord::{Cluster, ClusterConfig, Policy};
use hepq::datagen::generate_drellyan;
use hepq::engine::{columnar_exec, Backend, CompiledTapeBackend, Query, QueryKind};
use hepq::hist::H1;
use hepq::queryir::{self, table3};
use hepq::util::propkit::{check, Config};
use std::time::Duration;

/// interpreter == flat == tape == compiled (bit-exact), and all ≈ columnar.
#[test]
fn prop_all_execution_levels_agree() {
    let cfg = Config { cases: 10, ..Config::default() };
    let cases: [(&str, QueryKind); 4] = [
        (table3::MAX_PT, QueryKind::MaxPt),
        (table3::ETA_BEST, QueryKind::EtaBest),
        (table3::PTSUM_PAIRS, QueryKind::PtSumPairs),
        (table3::MASS_PAIRS, QueryKind::MassPairs),
    ];
    check(
        "all-execution-levels-agree",
        &cfg,
        |g| (1 + g.usize_to(400), g.rng.next_u64()),
        |&(n, seed)| {
            let cs = generate_drellyan(n, seed);
            for (src, kind) in cases {
                let (lo, hi) = kind.default_binning();
                let mut h_obj = H1::new(48, lo, hi);
                queryir::run_object_view(src, &cs, &mut h_obj)?;

                let prog = queryir::compile(src, &cs.schema)?;
                let mut h_flat = H1::new(48, lo, hi);
                queryir::flat::run(&prog, &cs, &mut h_flat)?;

                let tp = queryir::tape::compile(&prog);
                let mut h_tape = H1::new(48, lo, hi);
                queryir::tape::run(&tp, &cs, &mut h_tape)?;

                let cp = queryir::lower::lower(&prog)?;
                let mut h_comp = H1::new(48, lo, hi);
                queryir::lower::run(&cp, &cs, &mut h_comp)?;

                if h_obj.bins != h_flat.bins || h_obj.total() != h_flat.total() {
                    return Err(format!("{kind:?}: interp != flat"));
                }
                if h_obj.bins != h_tape.bins {
                    return Err(format!("{kind:?}: interp != tape"));
                }
                if h_obj.bins != h_comp.bins || h_obj.total() != h_comp.total() {
                    return Err(format!("{kind:?}: interp != compiled"));
                }

                // Hand-written loops compute in mixed f32/f64; totals are
                // exact, bins may migrate by an ulp at bin edges.
                let mut h_hand = H1::new(48, lo, hi);
                columnar_exec::run(kind, &cs, "muons", &mut h_hand)?;
                if h_hand.total() != h_comp.total() {
                    return Err(format!(
                        "{kind:?}: columnar total {} != compiled total {}",
                        h_hand.total(),
                        h_comp.total()
                    ));
                }
                let diff: f64 = h_hand
                    .bins
                    .iter()
                    .zip(&h_comp.bins)
                    .map(|(a, b)| (a - b).abs())
                    .sum();
                if diff > 4.0 {
                    return Err(format!("{kind:?}: columnar vs compiled bins differ by {diff}"));
                }
            }
            Ok(())
        },
    );
}

/// The compiled backend through the whole engine dispatch (`Backend`),
/// including kind→source rendering, equals the columnar backend.
#[test]
fn prop_backend_compiled_equals_columnar() {
    let cfg = Config { cases: 8, ..Config::default() };
    check(
        "backend-compiled-equals-columnar",
        &cfg,
        |g| (1 + g.usize_to(600), g.rng.next_u64()),
        |&(n, seed)| {
            let cs = generate_drellyan(n, seed);
            let be = Backend::compiled();
            for kind in QueryKind::ALL {
                let q = Query::new(kind, "dy", "muons");
                let mut h_col = H1::new(q.n_bins, q.lo, q.hi);
                Backend::Columnar.run(&q, &cs, &mut h_col)?;
                let mut h_comp = H1::new(q.n_bins, q.lo, q.hi);
                be.run(&q, &cs, &mut h_comp)?;
                if h_col.total() != h_comp.total() {
                    return Err(format!(
                        "{kind:?}: totals {} vs {}",
                        h_col.total(),
                        h_comp.total()
                    ));
                }
                let diff: f64 = h_col
                    .bins
                    .iter()
                    .zip(&h_comp.bins)
                    .map(|(a, b)| (a - b).abs())
                    .sum();
                if diff > 4.0 {
                    return Err(format!("{kind:?}: bins differ by {diff}"));
                }
            }
            Ok(())
        },
    );
}

/// A whole cluster running `Backend::CompiledTape` matches a local columnar
/// run, for kind queries and for free-form source queries.
#[test]
fn cluster_on_compiled_tape_matches_local() {
    let cs = generate_drellyan(12_000, 81);
    let cluster = Cluster::start(
        ClusterConfig {
            n_workers: 3,
            cache_bytes_per_worker: 256 << 20,
            policy: Policy::cache_aware(),
            fetch_delay_per_mib: Duration::ZERO,
            claim_ttl: Duration::from_secs(10),
            ..ClusterConfig::default()
        },
        Backend::compiled(),
    );
    cluster.catalog.register("dy", cs.clone(), 1_500);

    // Kind query.
    let q = Query::new(QueryKind::MassPairs, "dy", "muons");
    let res = cluster.run(&q).unwrap();
    let mut local = H1::new(q.n_bins, q.lo, q.hi);
    columnar_exec::run(q.kind, &cs, "muons", &mut local).unwrap();
    assert_eq!(res.hist.total(), local.total());
    assert_eq!(res.partitions, 8);

    // Source query distributed across partitions and workers.
    let src = "\
for event in dataset:
    for muon in event.muons:
        if muon.pt > 20 and muon.eta < 1.0 and muon.eta > -1.0:
            fill(muon.pt)
";
    let sq = Query::from_source(src, "dy").with_binning(64, 0.0, 128.0);
    let sres = cluster.run(&sq).unwrap();
    let mut slocal = H1::new(64, 0.0, 128.0);
    queryir::run_transformed(src, &cs, &mut slocal).unwrap();
    assert_eq!(sres.hist.bins, slocal.bins);
    assert_eq!(sres.hist.total(), slocal.total());
    assert!(sres.hist.total() > 0.0);
    cluster.shutdown();
}

/// The shared compile cache: one cluster-wide backend compiles each
/// distinct program once, no matter how many workers/partitions/queries.
#[test]
fn compile_cache_is_shared_across_workers() {
    let be = CompiledTapeBackend::new();
    let cluster = Cluster::start(
        ClusterConfig {
            n_workers: 4,
            cache_bytes_per_worker: 256 << 20,
            policy: Policy::AnyPull,
            fetch_delay_per_mib: Duration::ZERO,
            claim_ttl: Duration::from_secs(10),
            ..ClusterConfig::default()
        },
        Backend::CompiledTape(be.clone()),
    );
    cluster.catalog.register("dy", generate_drellyan(8_000, 82), 500);
    let q = Query::new(QueryKind::PtSumPairs, "dy", "muons");
    for _ in 0..3 {
        cluster.run(&q).unwrap();
    }
    // 16 partitions x 3 runs x 4 workers, still exactly one program.
    assert_eq!(be.compiled_count(), 1);
    cluster.shutdown();
}
