//! Equivalence properties of the morsel-driven parallel executor and the
//! chunked kernel.
//!
//! The guarantees under test:
//!   * `run_parallel` over any morsel size and thread count produces
//!     bin-identical histograms (bins, under/overflow, count) to the
//!     sequential `lower::run` — the `sum`/`sum2` moments are merged
//!     across morsel boundaries and may reassociate, so they are checked
//!     to a relative tolerance instead;
//!   * the chunked batch kernel — including **masked** (cut) bodies and
//!     **multi-Fill** bodies, which lower to one shared mask-and-fill
//!     batch pass — is **fully** bit-identical to the closure-graph fused
//!     loop, moments included, because it preserves element order and
//!     per-element arithmetic (randomized cut/fill program shapes below,
//!     NaN-producing expressions and weighted fills included);
//!   * both kernel families compose with morsel parallelism across the
//!     grid morsel ∈ {1, 7, 1024, whole} × threads ∈ {1, 2, 8}.

use hepq::datagen::{generate_drellyan, generate_ttbar};
use hepq::hist::H1;
use hepq::queryir::lower::{self, ParallelCfg};
use hepq::queryir::{self, table3};
use hepq::util::propkit::{check, Config, Gen};

/// Morsel merges reorder only the moment additions.
fn assert_morsel_equiv(seq: &H1, par: &H1, what: &str) {
    assert_eq!(seq.bins, par.bins, "{what}: bins");
    assert_eq!(seq.underflow, par.underflow, "{what}: underflow");
    assert_eq!(seq.overflow, par.overflow, "{what}: overflow");
    assert_eq!(seq.count, par.count, "{what}: count");
    for (name, a, b) in [("sum", seq.sum, par.sum), ("sum2", seq.sum2, par.sum2)] {
        let tol = 1e-9 * a.abs().max(b.abs()).max(1.0);
        assert!(
            (a - b).abs() <= tol,
            "{what}: {name} {a} vs {b} beyond merge tolerance"
        );
    }
}

/// The ISSUE grid: morsel sizes {1, 7, 1024, whole-partition} × thread
/// counts {1, 2, 8}, across a fused (chunked-kernel) query, a per-event
/// query and a quadratic pair query.
#[test]
fn morsel_grid_matches_sequential() {
    const N: usize = 5_000;
    let cs = generate_drellyan(N, 71);
    for (name, src) in [
        ("muon_pt", table3::MUON_PT),
        ("max_pt", table3::MAX_PT),
        ("mass_pairs", table3::MASS_PAIRS),
    ] {
        let prog = queryir::compile(src, &cs.schema).unwrap();
        let cp = lower::lower(&prog).unwrap();
        let mut seq = H1::new(64, 0.0, 128.0);
        lower::run(&cp, &cs, &mut seq).unwrap();
        for morsel_events in [1usize, 7, 1024, N] {
            for threads in [1usize, 2, 8] {
                let mut par = H1::new(64, 0.0, 128.0);
                let cfg = ParallelCfg {
                    threads,
                    morsel_events,
                };
                lower::run_parallel(&cp, &cs, &mut par, cfg).unwrap();
                assert_morsel_equiv(
                    &seq,
                    &par,
                    &format!("{name} morsel={morsel_events} threads={threads}"),
                );
            }
        }
    }
}

/// Randomized version: arbitrary sample sizes, seeds, morsel sizes and
/// thread counts agree with the sequential run.
#[test]
fn prop_parallel_equals_sequential() {
    let cfg = Config {
        cases: 12,
        ..Config::default()
    };
    check(
        "parallel-equals-sequential",
        &cfg,
        |g| {
            (
                1 + g.usize_to(3_000),
                g.rng.next_u64(),
                1 + g.usize_to(2_048),
                1 + g.usize_to(8),
            )
        },
        |&(n, seed, morsel_events, threads)| {
            let cs = generate_drellyan(n, seed);
            for src in [table3::MUON_PT, table3::ETA_BEST] {
                let prog = queryir::compile(src, &cs.schema)?;
                let cp = lower::lower(&prog)?;
                let mut seq = H1::new(48, -4.0, 120.0);
                lower::run(&cp, &cs, &mut seq)?;
                let mut par = H1::new(48, -4.0, 120.0);
                let pcfg = ParallelCfg {
                    threads,
                    morsel_events,
                };
                lower::run_parallel(&cp, &cs, &mut par, pcfg)?;
                if seq.bins != par.bins || seq.count != par.count {
                    return Err(format!(
                        "n={n} seed={seed} morsel={morsel_events} threads={threads}: \
                         parallel bins diverge"
                    ));
                }
            }
            Ok(())
        },
    );
}

/// The chunked kernel (used on the ttbar jet-pt fill) is bit-identical to
/// the closure-graph fused loop, including the running moments, with a
/// binning chosen so under- and overflow are both exercised.
#[test]
fn chunked_kernel_is_bit_identical_across_binnings() {
    let cs = generate_ttbar(4_000, 12, 7);
    let prog = queryir::compile(table3::JET_PT, &cs.schema).unwrap();
    let cp = lower::lower(&prog).unwrap();
    assert!(cp.has_chunked_kernel(), "jet-pt fill should take the chunked kernel");
    for (n_bins, lo, hi) in [(64, 0.0, 256.0), (17, 35.0, 90.0), (4, -50.0, -1.0)] {
        let mut chunked = H1::new(n_bins, lo, hi);
        lower::run(&cp, &cs, &mut chunked).unwrap();
        let mut scalar = H1::new(n_bins, lo, hi);
        lower::run_scalar(&cp, &cs, &mut scalar).unwrap();
        assert_eq!(chunked, scalar, "binning {n_bins}x[{lo},{hi})");
    }
}

/// Build a random cut/fill fused body: 1–3 fills under randomly chosen
/// cut structures (single cut, nested cuts, if/else), with values that can
/// go NaN (`sqrt`/`log` of a negative eta) and optional weights. Every
/// generated shape must lower to the masked chunked kernel.
fn random_cut_program(g: &mut Gen) -> String {
    fn pick_fill(g: &mut Gen) -> String {
        const VALUES: [&str; 5] = [
            "muon.pt",
            "sqrt(muon.eta)",
            "log(muon.eta)",
            "muon.pt * 0.5 + muon.eta",
            "sqrt(muon.pt * muon.pt + muon.phi * muon.phi)",
        ];
        const WEIGHTS: [&str; 3] = ["", ", 0.5", ", muon.pt * 0.25"];
        let v = VALUES[g.usize_to(VALUES.len() - 1)];
        let w = WEIGHTS[g.usize_to(WEIGHTS.len() - 1)];
        format!("fill({v}{w})")
    }
    fn pick_cond(g: &mut Gen) -> String {
        let t = g.usize_to(40) as f64 - 2.0;
        match g.usize_to(3) {
            0 => format!("muon.pt > {t}"),
            1 => format!("muon.eta < {t} and muon.pt > 5"),
            2 => format!("not muon.phi > {t}"),
            _ => format!("muon.pt > {t} or muon.eta > 0"),
        }
    }
    let body = match g.usize_to(3) {
        // One cut guarding two fills (shared mask).
        0 => format!(
            "        if {}:\n            {}\n            {}\n",
            pick_cond(g),
            pick_fill(g),
            pick_fill(g)
        ),
        // Nested cuts (mask conjunction) plus a sibling fill.
        1 => format!(
            "        if {}:\n            if {}:\n                {}\n            {}\n",
            pick_cond(g),
            pick_cond(g),
            pick_fill(g),
            pick_fill(g)
        ),
        // If/else (mask negation).
        2 => format!(
            "        if {}:\n            {}\n        else:\n            {}\n",
            pick_cond(g),
            pick_fill(g),
            pick_fill(g)
        ),
        // Top-level multi-fill with one cut fill.
        _ => format!(
            "        {}\n        if {}:\n            {}\n",
            pick_fill(g),
            pick_cond(g),
            pick_fill(g)
        ),
    };
    format!("for event in dataset:\n    for muon in event.muons:\n{body}")
}

/// Randomized cut/multi-fill bodies: every generated shape lowers to the
/// chunked kernel and agrees with the scalar closure loop to the last bit
/// (bins, under/overflow, count, sum, sum2) over random samples/binnings.
#[test]
fn prop_random_cut_bodies_chunked_bit_identical() {
    let cfg = Config {
        cases: 24,
        ..Config::default()
    };
    check(
        "cut-bodies-chunked-bit-identical",
        &cfg,
        |g| {
            (
                random_cut_program(g),
                1 + g.usize_to(2_500),
                g.rng.next_u64(),
            )
        },
        |(src, n, seed)| {
            let cs = generate_drellyan(*n, *seed);
            let prog = queryir::compile(src, &cs.schema)?;
            let cp = lower::lower(&prog)?;
            if !cp.has_chunked_kernel() {
                return Err(format!("did not lower chunked:\n{src}"));
            }
            for (n_bins, lo, hi) in [(64, -8.0, 120.0), (9, 3.0, 40.0)] {
                let mut chunked = H1::new(n_bins, lo, hi);
                lower::run(&cp, &cs, &mut chunked)?;
                let mut scalar = H1::new(n_bins, lo, hi);
                lower::run_scalar(&cp, &cs, &mut scalar)?;
                if chunked != scalar {
                    return Err(format!(
                        "chunked != scalar on {n_bins}x[{lo},{hi}) for:\n{src}"
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Multi-Fill + cut bodies across the full morsel grid: the masked chunked
/// kernel composes with morsel parallelism exactly like the Fill-only one.
/// Weights are dyadic (1 and 0.5), so bins and count are exact under any
/// merge association.
#[test]
fn multi_fill_morsel_grid_matches_sequential() {
    const N: usize = 5_000;
    let cs = generate_drellyan(N, 74);
    let src = "\
for event in dataset:
    for muon in event.muons:
        if muon.pt > 20:
            fill(muon.pt)
        fill(muon.eta, 0.5)
";
    let prog = queryir::compile(src, &cs.schema).unwrap();
    let cp = lower::lower(&prog).unwrap();
    assert!(cp.has_chunked_kernel(), "cut + two-fill body should lower chunked");
    let info = cp.chunked_info().unwrap();
    assert_eq!((info.fills, info.masked_fills), (2, 1));
    let mut seq = H1::new(64, -4.0, 128.0);
    lower::run(&cp, &cs, &mut seq).unwrap();
    for morsel_events in [1usize, 7, 1024, N] {
        for threads in [1usize, 2, 8] {
            let mut par = H1::new(64, -4.0, 128.0);
            let cfg = ParallelCfg {
                threads,
                morsel_events,
            };
            lower::run_parallel(&cp, &cs, &mut par, cfg).unwrap();
            assert_morsel_equiv(
                &seq,
                &par,
                &format!("two_fill morsel={morsel_events} threads={threads}"),
            );
        }
    }
}

/// Chunked + morsels composed: the parallel run of a fused query still
/// matches, and a whole-partition morsel equals the plain sequential run
/// bit-for-bit (single morsel → no merge reassociation at all).
#[test]
fn chunked_and_morsels_compose() {
    let cs = generate_drellyan(9_000, 73);
    let prog = queryir::compile(table3::MUON_PT, &cs.schema).unwrap();
    let cp = lower::lower(&prog).unwrap();
    assert!(cp.has_chunked_kernel());
    let mut seq = H1::new(64, 0.0, 128.0);
    lower::run(&cp, &cs, &mut seq).unwrap();

    let mut one_morsel = H1::new(64, 0.0, 128.0);
    let cfg = ParallelCfg {
        threads: 8,
        morsel_events: cs.n_events,
    };
    lower::run_parallel(&cp, &cs, &mut one_morsel, cfg).unwrap();
    assert_eq!(seq, one_morsel, "single morsel must be the sequential run");

    let mut many = H1::new(64, 0.0, 128.0);
    let cfg = ParallelCfg {
        threads: 4,
        morsel_events: 333,
    };
    lower::run_parallel(&cp, &cs, &mut many, cfg).unwrap();
    assert_morsel_equiv(&seq, &many, "chunked+morsels");
}
