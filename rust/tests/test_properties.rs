//! Property-based integration tests over the core invariants, using the
//! in-repo propkit harness (seeded, reproducible via HEPQ_PROP_SEED).

use hepq::columnar::explode::{explode, materialize_all, Value};
use hepq::columnar::schema::muon_event_schema;
use hepq::coord::{Cluster, ClusterConfig, Policy};
use hepq::datagen::generate_drellyan;
use hepq::engine::{columnar_exec, Backend, Query, QueryKind};
use hepq::format::{write_dataset, Codec, DatasetReader, WriteOptions};
use hepq::hist::H1;
use hepq::queryir::{self, table3};
use hepq::util::propkit::{check, Config, Gen};
use std::time::Duration;

fn random_events(g: &mut Gen, n: usize) -> Vec<Value> {
    (0..n)
        .map(|_| {
            let n_mu = g.rng.below(6) as usize;
            let muons: Vec<Value> = (0..n_mu)
                .map(|_| {
                    Value::rec(vec![
                        ("pt", Value::F64(g.rng.uniform(0.5, 150.0))),
                        ("eta", Value::F64(g.rng.uniform(-2.4, 2.4))),
                        ("phi", Value::F64(g.rng.uniform(-3.14, 3.14))),
                        ("charge", Value::I64(if g.rng.bool_with(0.5) { 1 } else { -1 })),
                    ])
                })
                .collect();
            Value::rec(vec![
                ("muons", Value::List(muons)),
                ("met", Value::F64(g.rng.exponential(20.0))),
            ])
        })
        .collect()
}

/// explode → materialize is the identity (modulo f32 storage, which these
/// generated values survive bit-for-bit in the f64 fields we compare).
#[test]
fn prop_explode_materialize_roundtrip() {
    let cfg = Config::default();
    check(
        "explode-materialize-roundtrip",
        &cfg,
        |g| {
            let n = g.usize_to(20);
            random_events(g, n)
        },
        |events| {
            let cs = explode(&muon_event_schema(), events).map_err(|e| e.to_string())?;
            cs.validate()?;
            let back = materialize_all(&cs)?;
            if back.len() != events.len() {
                return Err("length changed".into());
            }
            for (a, b) in events.iter().zip(&back) {
                let la = a.get("muons").unwrap().as_list().unwrap().len();
                let lb = b.get("muons").unwrap().as_list().unwrap().len();
                if la != lb {
                    return Err(format!("muon count {la} != {lb}"));
                }
            }
            Ok(())
        },
    );
}

/// Running a query on partitions and merging == running on the whole set.
#[test]
fn prop_partition_merge_equals_whole() {
    let cfg = Config { cases: 24, ..Config::default() };
    check(
        "partition-merge-equals-whole",
        &cfg,
        |g| {
            let n = 50 + g.usize_to(500);
            let part = 1 + g.usize_to(100);
            let seed = g.rng.next_u64();
            (n, part, seed)
        },
        |&(n, part, seed)| {
            let cs = generate_drellyan(n, seed);
            for kind in [QueryKind::MaxPt, QueryKind::MassPairs] {
                let (lo, hi) = kind.default_binning();
                let mut whole = H1::new(32, lo, hi);
                columnar_exec::run(kind, &cs, "muons", &mut whole)?;
                let mut merged = H1::new(32, lo, hi);
                for p in cs.partition(part) {
                    let mut h = H1::new(32, lo, hi);
                    columnar_exec::run(kind, &p, "muons", &mut h)?;
                    merged.merge(&h)?;
                }
                if merged.bins != whole.bins || merged.total() != whole.total() {
                    return Err(format!("{kind:?}: partitioned != whole"));
                }
            }
            Ok(())
        },
    );
}

/// femto-ROOT round-trips any generated dataset under any codec.
#[test]
fn prop_format_roundtrip_any_codec() {
    let cfg = Config { cases: 16, ..Config::default() };
    let dir = std::env::temp_dir().join("hepq-prop");
    std::fs::create_dir_all(&dir).unwrap();
    let mut case = 0u32;
    check(
        "format-roundtrip",
        &cfg,
        |g| {
            let n = g.usize_to(800);
            let seed = g.rng.next_u64();
            let codec = *g.rng.choose(&[Codec::None, Codec::Zstd(1), Codec::Flate]);
            let basket = 16 + g.usize_to(512);
            (n, seed, codec, basket)
        },
        |&(n, seed, codec, basket)| {
            case += 1;
            let cs = generate_drellyan(n, seed);
            let path = dir.join(format!("prop{case}.froot"));
            let wopts = WriteOptions { codec, basket_items: basket, ..WriteOptions::default() };
            write_dataset(&path, &cs, wopts)?;
            let mut r = DatasetReader::open(&path)?;
            let back = r.read_full()?;
            let _ = std::fs::remove_file(&path);
            if back != cs {
                return Err(format!("roundtrip failed (codec {codec:?}, basket {basket})"));
            }
            Ok(())
        },
    );
}

/// The §3 transformation preserves semantics on random data for every
/// Table-3 program.
#[test]
fn prop_transform_equivalence() {
    let cfg = Config { cases: 12, ..Config::default() };
    check(
        "transform-equivalence",
        &cfg,
        |g| (g.usize_to(400), g.rng.next_u64()),
        |&(n, seed)| {
            let cs = generate_drellyan(n.max(1), seed);
            for src in [table3::MAX_PT, table3::ETA_BEST, table3::PTSUM_PAIRS, table3::MASS_PAIRS] {
                let mut h_obj = H1::new(48, -10.0, 250.0);
                queryir::run_object_view(src, &cs, &mut h_obj)?;
                let mut h_flat = H1::new(48, -10.0, 250.0);
                queryir::run_transformed(src, &cs, &mut h_flat)?;
                if h_obj.bins != h_flat.bins || h_obj.total() != h_flat.total() {
                    return Err("interp != transformed".into());
                }
            }
            Ok(())
        },
    );
}

/// The distributed cluster returns the same histogram as a local run for
/// every policy, worker count and partitioning.
#[test]
fn prop_cluster_equals_local() {
    let cfg = Config { cases: 8, ..Config::default() };
    check(
        "cluster-equals-local",
        &cfg,
        |g| {
            let n = 500 + g.usize_to(4000);
            let part = 100 + g.usize_to(900);
            let workers = 1 + g.usize_to(5);
            let seed = g.rng.next_u64();
            let policy = *g.rng.choose(&[
                Policy::cache_aware(),
                Policy::AnyPull,
                Policy::RoundRobinPush,
            ]);
            (n, part, workers, seed, policy)
        },
        |&(n, part, workers, seed, policy)| {
            let cs = generate_drellyan(n, seed);
            let q = Query::new(QueryKind::PtSumPairs, "dy", "muons");
            let mut local = H1::new(q.n_bins, q.lo, q.hi);
            columnar_exec::run(q.kind, &cs, "muons", &mut local)?;

            let cluster = Cluster::start(
                ClusterConfig {
                    n_workers: workers,
                    cache_bytes_per_worker: 512 << 20,
                    policy,
                    fetch_delay_per_mib: Duration::ZERO,
                    claim_ttl: Duration::from_secs(10),
                    ..ClusterConfig::default()
                },
                Backend::Columnar,
            );
            cluster.catalog.register("dy", cs, part);
            let res = cluster.run(&q)?;
            cluster.shutdown();
            if res.hist.bins != local.bins {
                return Err(format!(
                    "policy {} x{workers} part {part}: cluster != local",
                    policy.name()
                ));
            }
            Ok(())
        },
    );
}
