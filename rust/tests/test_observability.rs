//! Observability end-to-end: the unified metrics registry served over the
//! wire (`metrics` op, JSON + Prometheus text exposition), per-query span
//! traces (`"trace":true` on a query, then the `trace` op), and the
//! golden wire schemas of the `stats`/`metrics`/`trace` responses — the
//! key sets dashboards and scrapers bind to, locked down so a rename is a
//! reviewed decision, not an accident.

use hepq::coord::{Cluster, ClusterConfig, Policy};
use hepq::datagen::generate_drellyan;
use hepq::engine::{Backend, Query, QueryKind};
use hepq::server::{Client, Server, ServerConfig};
use hepq::util::json::Json;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Barrier};
use std::time::Duration;

fn cluster(events: usize, seed: u64, part_events: usize) -> Arc<Cluster> {
    let c = Arc::new(Cluster::start(
        ClusterConfig {
            n_workers: 2,
            cache_bytes_per_worker: 64 << 20,
            policy: Policy::AnyPull,
            fetch_delay_per_mib: Duration::ZERO,
            claim_ttl: Duration::from_secs(10),
            ..ClusterConfig::default()
        },
        Backend::compiled(),
    ));
    c.catalog.register("dy", generate_drellyan(events, seed), part_events);
    c
}

fn start(cluster: Arc<Cluster>, cfg: ServerConfig) -> (String, std::thread::JoinHandle<()>, Arc<Server>) {
    let port = {
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap().port()
    };
    let addr = format!("127.0.0.1:{port}");
    let server = Arc::new(Server::with_config(cluster, cfg));
    let s2 = server.clone();
    let a2 = addr.clone();
    let t = std::thread::spawn(move || {
        s2.serve(&a2).unwrap();
    });
    for _ in 0..300 {
        if Client::connect(&addr).is_ok() {
            return (addr, t, server);
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    panic!("server did not come up on {addr}");
}

fn stop(server: &Server, t: std::thread::JoinHandle<()>) {
    server.shutdown_flag().store(true, Ordering::Relaxed);
    t.join().unwrap();
}

fn keys(j: &Json) -> Vec<String> {
    match j {
        Json::Obj(map) => map.keys().cloned().collect(),
        other => panic!("expected object, got {other}"),
    }
}

/// The `metrics` op must serve the registry's own handles, the collected
/// subsystem counters, and a well-formed Prometheus text exposition.
#[test]
fn metrics_op_exposes_registry_and_prometheus() {
    let (addr, t, server) = start(cluster(3_000, 81, 1_000), ServerConfig::default());
    let mut conn = Client::connect(&addr).unwrap();
    let q = Query::new(QueryKind::MaxPt, "dy", "muons");
    for _ in 0..2 {
        let resp = conn.query(&q, |_, _| {}).unwrap();
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp}");
    }

    let m = conn.request(&Json::obj(vec![("op", Json::str("metrics"))])).unwrap();
    assert_eq!(m.get("ok"), Some(&Json::Bool(true)), "{m}");
    let counters = m.get("counters").expect("counters block");
    let cnt = |k: &str| {
        counters
            .get(k)
            .and_then(|v| v.as_u64())
            .unwrap_or_else(|| panic!("counter '{k}' missing: {counters}"))
    };
    // Second run is a result-cache hit; both count as executed queries.
    assert_eq!(cnt("queries_executed"), 2);
    assert_eq!(cnt("result_cache.hits"), 1);
    // The miss path probes the cache twice (inline, then pre-execution).
    assert!(cnt("result_cache.misses") >= 1);
    assert_eq!(cnt("queries_cancelled"), 0);
    assert!(cnt("conns_accepted") >= 1);
    assert!(cnt("queue.accepted") >= 1);
    assert!(cnt("workers.tasks_done") >= 1);
    assert!(cnt("workers.events_processed") >= 3_000);
    // Present even when zero — scrapers need stable series.
    for k in [
        "placement.failovers",
        "placement.speculative_wins",
        "fusion.groups",
        "zones.partitions_scanned",
        "catalog.fetches",
        "kernel.allocation_events",
    ] {
        assert!(counters.get(k).is_some(), "counter '{k}' missing: {counters}");
    }
    let gauges = m.get("gauges").expect("gauges block");
    assert_eq!(gauges.get("active_conns").and_then(|v| v.as_u64()), Some(1));
    assert_eq!(gauges.get("queue.depth").and_then(|v| v.as_u64()), Some(0));
    assert!(gauges.get("live_workers").is_some());
    // Only the executed run observes latencies; inline cache hits skip
    // the queue entirely.
    let hist = m
        .get("histograms")
        .and_then(|h| h.get("query_exec_us"))
        .expect("query_exec_us histogram");
    assert_eq!(hist.get("count").and_then(|v| v.as_u64()), Some(1));
    let p50 = hist.get("p50").and_then(|v| v.as_u64()).unwrap();
    let max = hist.get("max").and_then(|v| v.as_u64()).unwrap();
    assert!(p50 <= max, "p50 {p50} > max {max}");

    // Prometheus text exposition: every line is a TYPE comment or a
    // `hepq_*` sample, and the executed-queries counter is in there.
    let prom = m.get("prometheus").and_then(|p| p.as_str()).expect("prometheus text");
    assert!(prom.contains("hepq_queries_executed 2"), "{prom}");
    assert!(prom.contains("# TYPE hepq_query_exec_us summary"));
    for line in prom.lines() {
        assert!(
            line.starts_with("# TYPE hepq_") || line.starts_with("hepq_"),
            "bad exposition line: {line}"
        );
    }
    stop(&server, t);
}

/// Golden wire schemas: the exact top-level key sets of the `stats`,
/// `metrics`, and `trace` responses, plus the `serving` block. BTreeMap
/// keys come back sorted, so the expectation lists are sorted too.
#[test]
fn golden_wire_schema_for_stats_metrics_trace() {
    let (addr, t, server) = start(cluster(2_000, 82, 1_000), ServerConfig::default());
    let mut conn = Client::connect(&addr).unwrap();

    let stats = conn.request(&Json::obj(vec![("op", Json::str("stats"))])).unwrap();
    assert_eq!(
        keys(&stats),
        [
            "bytes_fetched",
            "cache_hit_rate",
            "data_skipping",
            "ok",
            "placement",
            "result_cache_entries",
            "result_cache_evictions",
            "result_cache_hits",
            "result_cache_misses",
            "serving",
            "workers",
        ],
        "stats schema drifted"
    );
    assert_eq!(
        keys(stats.get("serving").unwrap()),
        [
            "active_conns",
            "avg_exec_ms",
            "avg_queue_ms",
            "conns_accepted",
            "fused_groups",
            "fused_queries",
            "queries_executed",
            "queue_depth",
            "queue_shed",
            "scans_saved",
        ],
        "serving block schema drifted"
    );

    let metrics = conn.request(&Json::obj(vec![("op", Json::str("metrics"))])).unwrap();
    assert_eq!(
        keys(&metrics),
        ["counters", "gauges", "histograms", "ok", "prometheus"],
        "metrics schema drifted"
    );

    let q = Query::new(QueryKind::FlatHist, "dy", "muons");
    let resp = conn.query_opts(&q, true, |_, _| {}).unwrap();
    assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp}");
    let tid = resp.get("trace_id").and_then(|v| v.as_u64()).expect("trace_id");
    std::thread::sleep(Duration::from_millis(100)); // let the executor end the root span
    let tr = conn
        .request(&Json::obj(vec![
            ("op", Json::str("trace")),
            ("id", Json::num(tid as f64)),
            ("chrome", Json::Bool(true)),
        ]))
        .unwrap();
    assert_eq!(
        keys(&tr),
        ["chrome", "dropped", "ok", "root", "spans", "trace_id"],
        "trace schema drifted"
    );
    stop(&server, t);
}

fn collect_names(node: &Json, out: &mut Vec<String>) {
    out.push(node.get("name").and_then(|v| v.as_str()).unwrap_or("?").to_string());
    if let Some(kids) = node.get("children").and_then(|v| v.as_arr()) {
        for k in kids {
            collect_names(k, out);
        }
    }
}

/// Every child span must lie within its parent's [start, end] interval —
/// the invariant that makes self-times meaningful.
fn check_nesting(node: &Json) {
    let start = node.get("start_us").and_then(|v| v.as_u64()).unwrap();
    let dur = node.get("dur_us").and_then(|v| v.as_u64()).unwrap();
    let name = node.get("name").and_then(|v| v.as_str()).unwrap_or("?");
    if let Some(kids) = node.get("children").and_then(|v| v.as_arr()) {
        for k in kids {
            let ks = k.get("start_us").and_then(|v| v.as_u64()).unwrap();
            let kd = k.get("dur_us").and_then(|v| v.as_u64()).unwrap();
            let kn = k.get("name").and_then(|v| v.as_str()).unwrap_or("?");
            assert!(ks >= start, "child {kn} starts ({ks}) before parent {name} ({start})");
            assert!(
                ks + kd <= start + dur,
                "child {kn} ends ({}) after parent {name} ({})",
                ks + kd,
                start + dur
            );
            check_nesting(k);
        }
    }
}

fn find<'a>(node: &'a Json, want: &str) -> Option<&'a Json> {
    if node.get("name").and_then(|v| v.as_str()) == Some(want) {
        return Some(node);
    }
    node.get("children")
        .and_then(|v| v.as_arr())
        .and_then(|kids| kids.iter().find_map(|k| find(k, want)))
}

/// A traced query must yield a span tree covering its whole lifecycle —
/// validate → queue → execute (with per-partition subtasks and the
/// reduction) → respond — properly nested, with the `execute` span's
/// duration matching the response's `exec_ms` within 5% (+scheduling
/// slack for sub-millisecond runs).
#[test]
fn traced_query_span_tree_accounts_for_exec_time() {
    let c = cluster(20_000, 83, 2_000);
    let (addr, t, server) = start(
        c,
        ServerConfig {
            batch_window_ms: 2,
            max_queue_depth: 256,
            max_conns: 64,
            executors: 1,
        },
    );
    let mut conn = Client::connect(&addr).unwrap();
    let q = Query::new(QueryKind::MassPairs, "dy", "muons");
    let resp = conn.query_opts(&q, true, |_, _| {}).unwrap();
    assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp}");
    let tid = resp.get("trace_id").and_then(|v| v.as_u64()).expect("trace_id in response");
    assert!(tid > 0);
    let exec_ms = resp.get("exec_ms").and_then(|v| v.as_f64()).unwrap();
    std::thread::sleep(Duration::from_millis(100)); // let the executor end the root span

    let tr = conn
        .request(&Json::obj(vec![("op", Json::str("trace")), ("id", Json::num(tid as f64))]))
        .unwrap();
    assert_eq!(tr.get("ok"), Some(&Json::Bool(true)), "{tr}");
    assert_eq!(tr.get("trace_id").and_then(|v| v.as_u64()), Some(tid));
    assert_eq!(tr.get("dropped").and_then(|v| v.as_u64()), Some(0));
    let root = tr.get("root").expect("root span");
    assert_eq!(root.get("name").and_then(|v| v.as_str()), Some("query"));

    let mut names = Vec::new();
    collect_names(root, &mut names);
    for want in ["validate_lower", "queue", "execute", "subtask", "reduce", "respond"] {
        assert!(names.iter().any(|n| n == want), "span '{want}' missing from {names:?}");
    }
    // 20k events at 2k per partition: every partition's scan is a span.
    assert!(
        names.iter().filter(|n| *n == "subtask").count() >= 10,
        "expected one subtask span per partition: {names:?}"
    );
    check_nesting(root);

    // The execute span wraps exactly the interval `exec_ms` measures, so
    // the tree accounts for the reported execution time.
    let execute = find(root, "execute").unwrap();
    let dur_ms = execute.get("dur_us").and_then(|v| v.as_u64()).unwrap() as f64 / 1e3;
    assert!(
        (dur_ms - exec_ms).abs() <= 0.05 * exec_ms + 3.0,
        "execute span {dur_ms} ms vs exec_ms {exec_ms} ms"
    );
    stop(&server, t);
}

/// With the tracer globally off and no `"trace":true`, responses carry no
/// trace id and the `trace` op has nothing to serve.
#[test]
fn untraced_queries_leave_no_trace() {
    let (addr, t, server) = start(cluster(2_000, 84, 1_000), ServerConfig::default());
    let mut conn = Client::connect(&addr).unwrap();
    let resp = conn.query(&Query::new(QueryKind::MaxPt, "dy", "muons"), |_, _| {}).unwrap();
    assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp}");
    assert!(resp.get("trace_id").is_none(), "untraced response carries trace_id: {resp}");
    let tr = conn.request(&Json::obj(vec![("op", Json::str("trace"))])).unwrap();
    assert_eq!(tr.get("ok"), Some(&Json::Bool(false)));
    assert_eq!(tr.get("error").and_then(|e| e.as_str()), Some("no such trace"));
    stop(&server, t);
}

/// Co-arriving traced queries that fuse into one shared scan still get
/// *independent* trace trees: distinct ids, each with its own execute
/// span and properly nested children.
#[test]
fn fused_members_get_independent_traces() {
    let (addr, t, server) = start(
        cluster(6_000, 85, 1_000),
        ServerConfig {
            batch_window_ms: 50,
            max_queue_depth: 256,
            max_conns: 64,
            executors: 1,
        },
    );
    let mix = [
        Query::new(QueryKind::FlatHist, "dy", "muons"),
        Query::new(QueryKind::MaxPt, "dy", "muons"),
    ];
    let barrier = Arc::new(Barrier::new(mix.len()));
    let handles: Vec<_> = mix
        .iter()
        .map(|q| {
            let addr = addr.clone();
            let barrier = barrier.clone();
            let q = q.clone();
            std::thread::spawn(move || {
                let mut conn = Client::connect(&addr).unwrap();
                barrier.wait();
                conn.query_opts(&q, true, |_, _| {}).unwrap()
            })
        })
        .collect();
    let resps: Vec<Json> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    std::thread::sleep(Duration::from_millis(100));
    let tids: Vec<u64> = resps
        .iter()
        .map(|r| {
            assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
            r.get("trace_id").and_then(|v| v.as_u64()).expect("trace_id")
        })
        .collect();
    assert_ne!(tids[0], tids[1], "fused members share a trace id");
    let mut conn = Client::connect(&addr).unwrap();
    for tid in tids {
        let tr = conn
            .request(&Json::obj(vec![("op", Json::str("trace")), ("id", Json::num(tid as f64))]))
            .unwrap();
        assert_eq!(tr.get("ok"), Some(&Json::Bool(true)), "{tr}");
        let root = tr.get("root").unwrap();
        assert_eq!(root.get("name").and_then(|v| v.as_str()), Some("query"));
        let mut names = Vec::new();
        collect_names(root, &mut names);
        assert!(names.iter().any(|n| n == "execute"), "{names:?}");
        check_nesting(root);
    }
    stop(&server, t);
}
