//! Concurrent serving end-to-end: many TCP clients issuing heterogeneous
//! queries at once must each get responses bit-identical to solo cluster
//! runs, shared-scan fusion counters must add up, the admission-control
//! path must shed and recover under a tiny queue cap, and connection churn
//! must not leak server-side state (the old thread-per-connection server
//! accumulated one JoinHandle per connection forever).

use hepq::coord::{Cluster, ClusterConfig, Policy};
use hepq::datagen::generate_drellyan;
use hepq::engine::{Backend, Query, QueryKind};
use hepq::hist::H1;
use hepq::server::{Client, Server, ServerConfig};
use hepq::util::json::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::Ordering;
use std::sync::{Arc, Barrier};
use std::time::Duration;

fn cluster(events: usize, seed: u64, part_events: usize) -> Arc<Cluster> {
    let c = Arc::new(Cluster::start(
        ClusterConfig {
            n_workers: 2,
            cache_bytes_per_worker: 64 << 20,
            policy: Policy::AnyPull,
            fetch_delay_per_mib: Duration::ZERO,
            claim_ttl: Duration::from_secs(10),
            ..ClusterConfig::default()
        },
        Backend::compiled(),
    ));
    c.catalog.register("dy", generate_drellyan(events, seed), part_events);
    c
}

type ServeThread = std::thread::JoinHandle<()>;

/// Start a server on a free port; returns (addr, serve thread, server).
/// The server stays reachable through the Arc so tests can inspect
/// internal state (live outbox slots) after the storm.
fn start(cluster: Arc<Cluster>, cfg: ServerConfig) -> (String, ServeThread, Arc<Server>) {
    let port = {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap().port()
    };
    let addr = format!("127.0.0.1:{port}");
    let server = Arc::new(Server::with_config(cluster, cfg));
    let s2 = server.clone();
    let a2 = addr.clone();
    let t = std::thread::spawn(move || {
        s2.serve(&a2).unwrap();
    });
    for _ in 0..300 {
        if Client::connect(&addr).is_ok() {
            return (addr, t, server);
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    panic!("server did not come up on {addr}");
}

fn stop(server: &Server, t: ServeThread) {
    server.shutdown_flag().store(true, Ordering::Relaxed);
    t.join().unwrap();
}

/// N concurrent clients, heterogeneous cache-missing queries (distinct
/// binnings and cut thresholds per client), fusion forced on with one
/// executor and a wide batching window so co-arriving queries are
/// guaranteed to share scans. Every response must be bit-identical to a
/// solo cluster run, and the stats op's serving counters must add up.
#[test]
fn concurrent_clients_bit_identical_and_fused() {
    const N: usize = 8;
    let c = cluster(8_000, 71, 1_000);
    let (addr, t, server) = start(
        c.clone(),
        ServerConfig {
            batch_window_ms: 50,
            max_queue_depth: 256,
            max_conns: 64,
            executors: 1,
        },
    );

    // Per-client query mixes: an unweighted flat fill, a quadratic pair
    // loop (distinct binning each), and a cut source query (distinct
    // threshold each) — all result-cache misses.
    let mixes: Vec<Vec<Query>> = (0..N)
        .map(|i| {
            let src = format!(
                "for event in dataset:\n    for muon in event.muons:\n        \
                 if muon.pt > {}:\n            fill(muon.pt)\n",
                20 + 2 * i
            );
            vec![
                Query::new(QueryKind::FlatHist, "dy", "muons").with_binning(64 + i, 0.0, 128.0),
                Query::new(QueryKind::MassPairs, "dy", "muons").with_binning(48 + i, 0.0, 128.0),
                Query::from_source(src, "dy"),
            ]
        })
        .collect();
    let solo: Vec<Vec<H1>> = mixes
        .iter()
        .map(|mix| mix.iter().map(|q| c.run(q).unwrap().hist).collect())
        .collect();

    let barrier = Arc::new(Barrier::new(N));
    let handles: Vec<_> = mixes
        .iter()
        .enumerate()
        .map(|(i, mix)| {
            let addr = addr.clone();
            let barrier = barrier.clone();
            let mix = mix.clone();
            std::thread::spawn(move || {
                let mut conn = Client::connect(&addr).unwrap();
                barrier.wait();
                let mut out = Vec::new();
                for q in &mix {
                    let resp = conn.query(q, |_, _| {}).unwrap();
                    assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "client {i}: {resp}");
                    out.push(resp);
                }
                out
            })
        })
        .collect();
    let responses: Vec<Vec<Json>> = handles.into_iter().map(|h| h.join().unwrap()).collect();

    // Bit-identity — the full histogram, running Σw·x moments included:
    // the waiter merges partition partials in partition order (BTreeMap),
    // so a fused or concurrent run associates every addition exactly like
    // the solo run, no matter which worker finished first.
    for (i, resps) in responses.iter().enumerate() {
        for (j, resp) in resps.iter().enumerate() {
            let h = H1::from_json(resp.get("hist").unwrap()).unwrap();
            assert_eq!(h, solo[i][j], "client {i} query {j} differs from solo");
            assert!(resp.get("queue_ms").is_some());
            assert!(resp.get("exec_ms").is_some());
        }
    }

    // Fusion counters: with one executor and a 50 ms window, the 8
    // simultaneously-submitted first-round queries must have shared scans.
    let mut stats_conn = Client::connect(&addr).unwrap();
    let req = Json::obj(vec![("op", Json::str("stats"))]);
    let stats = stats_conn.request(&req).unwrap();
    let serving = stats.get("serving").expect("serving block in stats");
    let get = |k: &str| serving.get(k).and_then(|v| v.as_u64()).unwrap_or(0);
    assert_eq!(get("queries_executed"), (N * 3) as u64);
    let groups = get("fused_groups");
    let fused = get("fused_queries");
    assert!(groups >= 1, "no fused groups formed: {serving}");
    // Every fused group has at least two members, and the first all-miss
    // round shares full-partition scans, so savings must be visible.
    assert!(fused >= 2 * groups, "fused_queries {fused} < 2 * groups {groups}");
    assert!(get("scans_saved") >= 1);
    assert_eq!(get("queue_shed"), 0);
    assert!(responses.iter().flatten().any(|r| {
        r.get("fused_with").and_then(|v| v.as_u64()).unwrap_or(0) >= 1
    }));

    stop(&server, t);
}

/// Cross-run reproducibility of fused groups: two identically-seeded,
/// identically-partitioned server stacks serve the same co-arriving mix
/// (an aux-bearing AGC source query included) with wholesale bit-identical
/// responses. Whether and how queries fuse may differ between the runs;
/// the histograms — primary and the `hists` aux array — must not.
#[test]
fn fused_groups_reproduce_across_runs() {
    let aux_src = "\
for event in dataset:
    for muon in event.muons:
        if muon.pt > 21:
            fill(muon.pt)
            fill2(muon.pt, muon.eta)
            fill_vars(muon.pt, 0.5, 1.0, 2.0)
";
    let mix: Vec<Query> = vec![
        Query::from_source(aux_src, "dy").with_y_binning(16, -4.0, 4.0),
        Query::new(QueryKind::MassPairs, "dy", "muons"),
        Query::new(QueryKind::MaxPt, "dy", "muons").with_binning(48, 0.0, 96.0),
        Query::new(QueryKind::FlatHist, "dy", "muons"),
    ];
    let run_once = |mix: &[Query]| -> Vec<Json> {
        let c = cluster(6_000, 74, 1_000);
        let (addr, t, server) = start(
            c,
            ServerConfig {
                batch_window_ms: 50,
                max_queue_depth: 256,
                max_conns: 64,
                executors: 1,
            },
        );
        let barrier = Arc::new(Barrier::new(mix.len()));
        let handles: Vec<_> = mix
            .iter()
            .map(|q| {
                let addr = addr.clone();
                let barrier = barrier.clone();
                let q = q.clone();
                std::thread::spawn(move || {
                    let mut conn = Client::connect(&addr).unwrap();
                    barrier.wait();
                    conn.query(&q, |_, _| {}).unwrap()
                })
            })
            .collect();
        let out = handles.into_iter().map(|h| h.join().unwrap()).collect();
        stop(&server, t);
        out
    };
    let a = run_once(&mix);
    let b = run_once(&mix);
    for (i, (ra, rb)) in a.iter().zip(&b).enumerate() {
        assert_eq!(ra.get("ok"), Some(&Json::Bool(true)), "query {i}: {ra}");
        assert_eq!(ra.get("hist"), rb.get("hist"), "query {i}: primary drifted across runs");
        assert_eq!(ra.get("hists"), rb.get("hists"), "query {i}: aux drifted across runs");
    }
    // The aux-bearing member really carried its sinks over the wire.
    let aux = a[0].get("hists").expect("aux query carries hists").as_arr().unwrap();
    assert_eq!(aux.len(), 4, "h2 + 3 weight variations");
}

/// Under a queue cap of 1 with a single executor, a burst of pipelined
/// queries on one connection must shed with the structured overload
/// response — and the connection must keep working afterwards.
#[test]
fn overload_sheds_and_recovers() {
    let (addr, t, server) = start(
        cluster(3_000, 72, 1_000),
        ServerConfig {
            batch_window_ms: 0,
            max_queue_depth: 1,
            max_conns: 64,
            executors: 1,
        },
    );

    let q = Query::new(QueryKind::MassPairs, "dy", "muons");
    let mut req = q.to_json();
    if let Json::Obj(map) = &mut req {
        map.insert("op".into(), Json::str("query"));
    }
    let line = format!("{req}\n");

    // Pipeline 4 copies without reading: the first is admitted (and at
    // most one more queues behind it); the rest overflow the depth-1 cap.
    let mut stream = TcpStream::connect(&addr).unwrap();
    for _ in 0..4 {
        stream.write_all(line.as_bytes()).unwrap();
    }
    let mut rd = BufReader::new(stream.try_clone().unwrap());
    let (mut ok, mut shed) = (0, 0);
    let mut finals = 0;
    while finals < 4 {
        let mut l = String::new();
        assert!(rd.read_line(&mut l).unwrap() > 0, "server closed early");
        let j = Json::parse(l.trim()).unwrap();
        if j.get("progress").is_some() {
            continue;
        }
        finals += 1;
        if j.get("error").and_then(|e| e.as_str()) == Some("overloaded") {
            let retry = j.get("retry_after_ms").and_then(|v| v.as_u64()).unwrap();
            assert!(retry >= 10, "retry_after_ms too small: {retry}");
            shed += 1;
        } else {
            assert_eq!(j.get("ok"), Some(&Json::Bool(true)), "{j}");
            ok += 1;
        }
    }
    assert!(ok >= 1, "no query survived the burst");
    assert!(shed >= 1, "depth-1 cap never shed");

    // Recovery: the same connection serves the query fine after backoff.
    std::thread::sleep(Duration::from_millis(50));
    stream.write_all(line.as_bytes()).unwrap();
    loop {
        let mut l = String::new();
        assert!(rd.read_line(&mut l).unwrap() > 0);
        let j = Json::parse(l.trim()).unwrap();
        if j.get("progress").is_some() {
            continue;
        }
        assert_eq!(j.get("ok"), Some(&Json::Bool(true)), "did not recover: {j}");
        break;
    }

    let mut stats_conn = Client::connect(&addr).unwrap();
    let req = Json::obj(vec![("op", Json::str("stats"))]);
    let stats = stats_conn.request(&req).unwrap();
    let serving = stats.get("serving").unwrap();
    assert!(serving.get("queue_shed").and_then(|v| v.as_u64()).unwrap() >= 1);

    stop(&server, t);
}

/// Regression for the old serve-loop JoinHandle leak: 1 000 sequential
/// connect/ping/disconnect cycles must not accumulate per-connection
/// server state. The reactor owns no per-connection threads; its live
/// outbox slots and the active_conns gauge must track only the
/// connections that still exist.
#[test]
fn connection_churn_leaves_no_state_behind() {
    const CHURN: usize = 1_000;
    let (addr, t, server) = start(cluster(2_000, 73, 1_000), ServerConfig::default());

    let ping = Json::obj(vec![("op", Json::str("ping"))]);
    for i in 0..CHURN {
        let mut conn = Client::connect(&addr).unwrap_or_else(|e| panic!("connect {i}: {e}"));
        let resp = conn.request(&ping).unwrap();
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
        // conn drops here: the reactor must reap it on its next pass.
    }
    // Let the reactor process the last FINs.
    std::thread::sleep(Duration::from_millis(200));
    assert_eq!(server.live_slots(), 0, "outbox slots leaked after churn");

    let mut conn = Client::connect(&addr).unwrap();
    let req = Json::obj(vec![("op", Json::str("stats"))]);
    let stats = conn.request(&req).unwrap();
    let serving = stats.get("serving").unwrap();
    let get = |k: &str| serving.get(k).and_then(|v| v.as_u64()).unwrap_or(u64::MAX);
    assert_eq!(get("active_conns"), 1, "gauge out of sync: {serving}");
    // + 2: the is-it-up probe in start() and this stats connection.
    assert_eq!(get("conns_accepted"), (CHURN + 2) as u64);
    assert_eq!(get("queue_depth"), 0);
    assert_eq!(server.live_slots(), 1);

    stop(&server, t);
}
