//! Failure injection: the behaviours that make the Figure-2 design safe —
//! claim expiry after worker death, heartbeat-driven failover, straggler
//! speculation, duplicate suppression, corrupt files, and malformed
//! queries — exercised end to end.
//!
//! The cluster-level tests form a failure-schedule grid (kill before a
//! claim, kill *holding* a claim, double failure on both affinity
//! replicas, kill during a fused group, straggler speculation), and every
//! schedule must produce a histogram **bit-identical** to the unfailed
//! run: the partition-ordered final reduction plus document dedup make
//! recovery invisible in the result, visible only in the telemetry.

use hepq::coord::board::{Subtask, SubtaskId, TaskBoard};
use hepq::coord::docstore::{DocStore, PartialDoc};
use hepq::coord::{Cluster, ClusterConfig, ClusterError, Policy};
use hepq::datagen::generate_drellyan;
use hepq::engine::{Backend, Query, QueryKind};
use hepq::format::{write_dataset, DatasetReader, WriteOptions};
use hepq::hist::H1;
use std::time::Duration;

/// A worker that claims a subtask and dies (never completes): the claim
/// expires and another worker finishes the query — no lost subtasks.
#[test]
fn dead_worker_claim_is_reclaimed() {
    let board = TaskBoard::new(Duration::from_millis(30));
    board.advertise(
        (0..4)
            .map(|p| Subtask {
                id: SubtaskId { query_id: 1, partition: p },
                dataset: "dy".into(),
                assigned_to: None,
                co_queries: Vec::new(),
                affinity: Vec::new(),
            })
            .collect(),
    );
    // "Worker 0" claims one subtask and crashes.
    let doomed = board.claim(0, |_| true).unwrap();
    // A healthy worker drains the rest.
    let mut healthy = Vec::new();
    while let Some(t) = board.claim(1, |_| true) {
        board.complete(&t.id);
        healthy.push(t.id.partition);
    }
    assert_eq!(healthy.len(), 3);
    assert!(!board.all_done(1));
    // After the TTL the dead claim reopens and the healthy worker finishes.
    std::thread::sleep(Duration::from_millis(50));
    let reclaimed = board.claim(1, |_| true).expect("expired claim reopens");
    assert_eq!(reclaimed.id, doomed.id);
    board.complete(&reclaimed.id);
    assert!(board.all_done(1));
}

/// If the dead worker was merely slow and completes after reclamation, the
/// duplicate partial is dropped and the merged total stays correct.
#[test]
fn straggler_duplicate_is_dropped() {
    let store = DocStore::new();
    let id = SubtaskId { query_id: 1, partition: 0 };
    let mut h = H1::new(4, 0.0, 4.0);
    h.fill(1.0);
    assert!(store.insert(PartialDoc {
        id: id.clone(),
        worker: 1,
        hist: h.clone(),
        aux: Vec::new(),
        events_processed: 10,
        chunks: Default::default(),
        error: None,
    }));
    // The straggler finishes the same subtask later.
    assert!(!store.insert(PartialDoc {
        id,
        worker: 0,
        hist: h,
        aux: Vec::new(),
        events_processed: 10,
        chunks: Default::default(),
        error: None,
    }));
    let docs = store.drain(1);
    assert_eq!(docs.len(), 1);
    assert_eq!(docs[0].worker, 1);
    assert_eq!(store.duplicates(), 1);
}

/// A cluster with an extreme straggler still converges to the exact result
/// under the pull policies.
#[test]
fn cluster_converges_despite_straggler() {
    let cs = generate_drellyan(8_000, 71);
    let q = Query::new(QueryKind::MaxPt, "dy", "muons");
    let mut local = H1::new(q.n_bins, q.lo, q.hi);
    Backend::Columnar.run(&q, &cs, &mut local).unwrap();

    let cluster = Cluster::start(
        ClusterConfig {
            n_workers: 3,
            cache_bytes_per_worker: 256 << 20,
            policy: Policy::cache_aware(),
            fetch_delay_per_mib: Duration::ZERO,
            claim_ttl: Duration::from_secs(5),
            straggler: Some((0, Duration::from_millis(40))),
            ..ClusterConfig::default()
        },
        Backend::Columnar,
    );
    cluster.catalog.register("dy", cs, 500);
    let res = cluster.run(&q).unwrap();
    assert_eq!(res.hist.bins, local.bins);
    assert_eq!(res.partitions, 16);
    cluster.shutdown();
}

// ------------------------------------------------- failure-schedule grid

/// A cluster tuned for failure drills: fast heartbeat detection against a
/// deliberately generous claim TTL, so any timely recovery observed is the
/// health-based failover path, never TTL expiry. Speculation is off unless
/// a test turns it on — it would blur failover attribution.
fn churn_cluster(n_workers: usize, events: usize, seed: u64, part_events: usize) -> Cluster {
    let c = Cluster::start(
        ClusterConfig {
            n_workers,
            cache_bytes_per_worker: 256 << 20,
            policy: Policy::cache_aware(),
            // ~0.1 MiB partitions => a few ms per miss: long enough that a
            // query overlaps the failure window, short enough for CI.
            fetch_delay_per_mib: Duration::from_millis(40),
            claim_ttl: Duration::from_secs(30),
            heartbeat_timeout: Duration::from_millis(150),
            speculation_factor: 0.0,
            ..ClusterConfig::default()
        },
        Backend::Columnar,
    );
    c.catalog.register("dy", generate_drellyan(events, seed), part_events);
    c
}

/// The bit-exactness oracle: the same query on an identically configured
/// unfailed cluster. Partition-ordered reduction makes the two runs
/// `H1`-equal down to `sum`/`sum2`, whatever recovery happened.
fn clean_reference(events: usize, seed: u64, part_events: usize, q: &Query) -> H1 {
    let c = Cluster::start(
        ClusterConfig {
            n_workers: 2,
            cache_bytes_per_worker: 256 << 20,
            policy: Policy::cache_aware(),
            fetch_delay_per_mib: Duration::ZERO,
            ..ClusterConfig::default()
        },
        Backend::Columnar,
    );
    c.catalog.register("dy", generate_drellyan(events, seed), part_events);
    let hist = c.run(q).unwrap().hist;
    c.shutdown();
    hist
}

/// Schedule: kill a worker *before* it can claim anything. The submit
/// hashes partitions over the remaining live workers and the query
/// completes bit-exactly.
#[test]
fn kill_before_claim_converges_exactly() {
    let q = Query::new(QueryKind::MassPairs, "dy", "muons");
    let want = clean_reference(12_000, 74, 1_000, &q);
    let c = churn_cluster(3, 12_000, 74, 1_000);
    assert!(c.kill_worker(0));
    assert_eq!(c.n_workers(), 2);
    let res = c.run(&q).unwrap();
    assert_eq!(res.hist, want, "exact incl. sum/sum2 despite dead worker");
    assert_eq!(res.partitions, 12);
    c.shutdown();
}

/// Schedule: a worker claims a subtask and dies *holding* it (the hard
/// case — the subtask is neither open nor completed). The heartbeat
/// reaper reopens it well before the 30 s claim TTL and a replica
/// finishes; the result is bit-exact and the failover is counted.
#[test]
fn kill_holding_claim_fails_over_exactly() {
    let q = Query::new(QueryKind::MaxPt, "dy", "muons");
    let want = clean_reference(12_000, 75, 1_000, &q);
    let c = churn_cluster(2, 12_000, 75, 1_000);
    c.inject_abandon(0, 1);
    // The doomed worker races the healthy one for its first claim; retry
    // until the schedule actually fired (it almost always does at once).
    for _ in 0..10 {
        let t0 = std::time::Instant::now();
        let res = c.run(&q).unwrap();
        assert_eq!(res.hist, want, "exact incl. sum/sum2 under failover");
        if c.placement_stats().failovers >= 1 {
            assert!(
                t0.elapsed() < Duration::from_secs(10),
                "failover must beat the 30s claim TTL"
            );
            c.shutdown();
            return;
        }
    }
    panic!("abandon injection never fired across 10 runs");
}

/// Schedule: double failure — both affinity replicas of a partition die
/// mid-query (each holding a claim). Every subtask they owned fails over
/// to the single survivor; grace windows for dead owners are waived, and
/// the result stays bit-exact.
#[test]
fn double_failure_on_both_replicas_converges() {
    let q = Query::new(QueryKind::MassPairs, "dy", "muons");
    let want = clean_reference(16_000, 76, 1_000, &q);
    let c = churn_cluster(3, 16_000, 76, 1_000);
    // k = 2: partition 0 has exactly two owners; arrange for both to die
    // on their next claim.
    let owners = c.partition_affinity("dy", 0);
    assert_eq!(owners.len(), 2);
    for &w in &owners {
        c.inject_abandon(w, 1);
    }
    for _ in 0..10 {
        let res = c.run(&q).unwrap();
        assert_eq!(res.hist, want, "exact incl. sum/sum2 under double failure");
        if c.n_workers() == 1 {
            // Both owners died holding a claim: two rescued subtasks.
            assert!(c.placement_stats().failovers >= 2);
            c.shutdown();
            return;
        }
    }
    panic!("double-failure schedule never fully fired across 10 runs");
}

/// Schedule: a worker dies while holding a *fused* subtask (several
/// queries riding one scan). The failover re-runs the whole shared scan
/// and every member query stays bit-exact.
#[test]
fn kill_during_fused_group_keeps_members_exact() {
    let queries = [
        Query::new(QueryKind::FlatHist, "dy", "muons"),
        Query::new(QueryKind::MassPairs, "dy", "muons"),
        Query::new(QueryKind::MaxPt, "dy", "muons"),
    ];
    let want: Vec<H1> = queries
        .iter()
        .map(|q| clean_reference(12_000, 77, 1_000, q))
        .collect();
    let c = churn_cluster(2, 12_000, 77, 1_000);
    c.inject_abandon(1, 1);
    let handles = c.submit_fused(&queries).unwrap();
    for ((h, q), want) in handles.iter().zip(&queries).zip(&want) {
        let res = c.wait(h, q).unwrap();
        assert_eq!(&res.hist, want, "{}: exact under fused-group failure", q.kind.artifact());
    }
    c.shutdown();
}

/// Schedule: no failure, just a severe straggler. With heartbeats healthy
/// (generous timeout) the *speculation* path re-advertises the slow claim
/// once the running latency estimate is exceeded; the fast copy wins, the
/// straggler's late duplicate is dropped, and the query finishes long
/// before the straggler wakes.
#[test]
fn speculation_rescues_straggler_without_declaring_it_dead() {
    let q = Query::new(QueryKind::MaxPt, "dy", "muons");
    let c = Cluster::start(
        ClusterConfig {
            n_workers: 2,
            cache_bytes_per_worker: 256 << 20,
            policy: Policy::cache_aware(),
            fetch_delay_per_mib: Duration::ZERO,
            claim_ttl: Duration::from_secs(30),
            // Heartbeats stay healthy: the straggler must NOT be declared
            // dead — only speculation may rescue its claim.
            heartbeat_timeout: Duration::from_secs(30),
            speculation_factor: 2.0,
            speculation_min: Duration::from_millis(50),
            ..ClusterConfig::default()
        },
        Backend::Columnar,
    );
    c.catalog.register("dy", generate_drellyan(8_000, 78), 1_000);
    // Warm-up run: builds the latency estimate (>= 3 samples) the
    // speculation threshold multiplies.
    let want = c.run(&q).unwrap().hist;
    // Now worker 0 straggles hard: 1.5 s of simulated load per subtask,
    // slept while holding the claim.
    c.set_handicap(0, Duration::from_millis(1_500));
    let t0 = std::time::Instant::now();
    let res = c.run(&q).unwrap();
    let latency = t0.elapsed();
    assert_eq!(res.hist, want, "exact incl. sum/sum2 under speculation");
    assert!(
        c.placement_stats().speculative_reopens >= 1,
        "straggling claim was never speculatively re-advertised"
    );
    assert_eq!(
        c.placement_stats().failovers,
        0,
        "healthy straggler must not be treated as dead"
    );
    assert!(
        latency < Duration::from_millis(1_400),
        "query waited for the straggler ({latency:?}) instead of speculating"
    );
    c.shutdown();
}

/// Schedule: worker death with nobody left. The query deadline expires and
/// reports a structured error listing exactly which subtasks are
/// outstanding — never a silent stall — and a joining worker restores
/// service for the retry.
#[test]
fn deadline_expiry_reports_outstanding_then_join_recovers() {
    let q = Query::new(QueryKind::MaxPt, "dy", "muons");
    let c = Cluster::start(
        ClusterConfig {
            n_workers: 1,
            cache_bytes_per_worker: 256 << 20,
            policy: Policy::cache_aware(),
            fetch_delay_per_mib: Duration::ZERO,
            query_deadline: Duration::from_millis(250),
            heartbeat_timeout: Duration::from_millis(150),
            ..ClusterConfig::default()
        },
        Backend::Columnar,
    );
    c.catalog.register("dy", generate_drellyan(4_000, 79), 1_000);
    c.kill_worker(0);
    std::thread::sleep(Duration::from_millis(30));
    let h = c.submit(q.clone()).unwrap();
    match c.wait(&h, &q) {
        Err(ClusterError::Timeout { merged, total, outstanding, .. }) => {
            assert_eq!(merged, 0);
            assert_eq!(total, 4);
            assert_eq!(outstanding.len(), 4, "every unfinished subtask listed");
        }
        other => panic!("expected structured timeout, got {other:?}"),
    }
    // Join churn: a fresh worker makes the retry succeed.
    c.spawn_worker();
    let res = c.run(&q).unwrap();
    assert_eq!(res.partitions, 4);
    assert_eq!(c.pending_docs(), 0, "no residue after timeout + retry");
    c.shutdown();
}

/// Corrupt and truncated files are rejected with errors, not panics.
#[test]
fn corrupt_files_are_rejected() {
    let dir = std::env::temp_dir().join("hepq-failinj");
    std::fs::create_dir_all(&dir).unwrap();

    // Truncated mid-baskets.
    let cs = generate_drellyan(2_000, 72);
    let path = dir.join("trunc.froot");
    write_dataset(&path, &cs, WriteOptions::default()).unwrap();
    let full = std::fs::read(&path).unwrap();
    std::fs::write(&path, &full[..full.len() / 2]).unwrap();
    match DatasetReader::open(&path) {
        Err(_) => {}
        Ok(mut r) => {
            // Header may survive (it is at the end... it is not: header_pos
            // points past the truncation), but reads must fail cleanly.
            assert!(r.read_full().is_err());
        }
    }

    // Bit-flipped header area.
    let path2 = dir.join("flip.froot");
    let mut bytes = full.clone();
    let n = bytes.len();
    bytes[n - 20] ^= 0xFF;
    std::fs::write(&path2, &bytes).unwrap();
    match DatasetReader::open(&path2) {
        Err(_) => {}
        Ok(mut r) => {
            let _ = r.read_full(); // must not panic; error or garbage-free data
        }
    }

    // Wrong magic.
    let path3 = dir.join("magic.froot");
    let mut bytes = full;
    bytes[0] ^= 0xFF;
    std::fs::write(&path3, &bytes).unwrap();
    assert!(DatasetReader::open(&path3).is_err());
}

/// Malformed queries fail fast at submit, not in workers.
#[test]
fn malformed_queries_rejected_cleanly() {
    let cluster = Cluster::start(
        ClusterConfig {
            n_workers: 1,
            cache_bytes_per_worker: 64 << 20,
            policy: Policy::AnyPull,
            fetch_delay_per_mib: Duration::ZERO,
            claim_ttl: Duration::from_secs(5),
            ..ClusterConfig::default()
        },
        Backend::Columnar,
    );
    cluster.catalog.register("dy", generate_drellyan(1_000, 73), 500);
    // Unknown dataset.
    assert!(cluster.submit(Query::new(QueryKind::MaxPt, "nope", "muons")).is_err());
    // Unknown list: submit succeeds (partitions exist) but the query
    // errors in workers; claims expire and wait_with_progress times out
    // rather than hanging forever — use cancellation to verify liveness.
    let bad = Query::new(QueryKind::MaxPt, "dy", "jets");
    let h = cluster.submit(bad.clone()).unwrap();
    let res = cluster.wait_with_progress(&h, &bad, |done, _, _| done == 0 && false);
    assert!(matches!(res, Err(ClusterError::Cancelled)));
    // Cluster still serves good queries afterwards.
    let good = Query::new(QueryKind::MaxPt, "dy", "muons");
    assert!(cluster.run(&good).is_ok());
    cluster.shutdown();
}
