//! Failure injection: the behaviours that make the Figure-2 design safe —
//! claim expiry after worker death, duplicate suppression, corrupt files,
//! and malformed queries — exercised end to end.

use hepq::coord::board::{Subtask, SubtaskId, TaskBoard};
use hepq::coord::docstore::{DocStore, PartialDoc};
use hepq::coord::{Cluster, ClusterConfig, Policy};
use hepq::datagen::generate_drellyan;
use hepq::engine::{Backend, Query, QueryKind};
use hepq::format::{write_dataset, DatasetReader, WriteOptions};
use hepq::hist::H1;
use std::time::Duration;

/// A worker that claims a subtask and dies (never completes): the claim
/// expires and another worker finishes the query — no lost subtasks.
#[test]
fn dead_worker_claim_is_reclaimed() {
    let board = TaskBoard::new(Duration::from_millis(30));
    board.advertise(
        (0..4)
            .map(|p| Subtask {
                id: SubtaskId { query_id: 1, partition: p },
                dataset: "dy".into(),
                assigned_to: None,
            })
            .collect(),
    );
    // "Worker 0" claims one subtask and crashes.
    let doomed = board.claim(0, |_| true).unwrap();
    // A healthy worker drains the rest.
    let mut healthy = Vec::new();
    while let Some(t) = board.claim(1, |_| true) {
        board.complete(&t.id);
        healthy.push(t.id.partition);
    }
    assert_eq!(healthy.len(), 3);
    assert!(!board.all_done(1));
    // After the TTL the dead claim reopens and the healthy worker finishes.
    std::thread::sleep(Duration::from_millis(50));
    let reclaimed = board.claim(1, |_| true).expect("expired claim reopens");
    assert_eq!(reclaimed.id, doomed.id);
    board.complete(&reclaimed.id);
    assert!(board.all_done(1));
}

/// If the dead worker was merely slow and completes after reclamation, the
/// duplicate partial is dropped and the merged total stays correct.
#[test]
fn straggler_duplicate_is_dropped() {
    let store = DocStore::new();
    let id = SubtaskId { query_id: 1, partition: 0 };
    let mut h = H1::new(4, 0.0, 4.0);
    h.fill(1.0);
    assert!(store.insert(PartialDoc {
        id: id.clone(),
        worker: 1,
        hist: h.clone(),
        events_processed: 10,
        chunks: Default::default(),
    }));
    // The straggler finishes the same subtask later.
    assert!(!store.insert(PartialDoc {
        id,
        worker: 0,
        hist: h,
        events_processed: 10,
        chunks: Default::default(),
    }));
    let docs = store.drain(1);
    assert_eq!(docs.len(), 1);
    assert_eq!(docs[0].worker, 1);
    assert_eq!(store.duplicates(), 1);
}

/// A cluster with an extreme straggler still converges to the exact result
/// under the pull policies.
#[test]
fn cluster_converges_despite_straggler() {
    let cs = generate_drellyan(8_000, 71);
    let q = Query::new(QueryKind::MaxPt, "dy", "muons");
    let mut local = H1::new(q.n_bins, q.lo, q.hi);
    Backend::Columnar.run(&q, &cs, &mut local).unwrap();

    let cluster = Cluster::start(
        ClusterConfig {
            n_workers: 3,
            cache_bytes_per_worker: 256 << 20,
            policy: Policy::cache_aware(),
            fetch_delay_per_mib: Duration::ZERO,
            claim_ttl: Duration::from_secs(5),
            straggler: Some((0, Duration::from_millis(40))),
        },
        Backend::Columnar,
    );
    cluster.catalog.register("dy", cs, 500);
    let res = cluster.run(&q).unwrap();
    assert_eq!(res.hist.bins, local.bins);
    assert_eq!(res.partitions, 16);
    cluster.shutdown();
}

/// Corrupt and truncated files are rejected with errors, not panics.
#[test]
fn corrupt_files_are_rejected() {
    let dir = std::env::temp_dir().join("hepq-failinj");
    std::fs::create_dir_all(&dir).unwrap();

    // Truncated mid-baskets.
    let cs = generate_drellyan(2_000, 72);
    let path = dir.join("trunc.froot");
    write_dataset(&path, &cs, WriteOptions::default()).unwrap();
    let full = std::fs::read(&path).unwrap();
    std::fs::write(&path, &full[..full.len() / 2]).unwrap();
    match DatasetReader::open(&path) {
        Err(_) => {}
        Ok(mut r) => {
            // Header may survive (it is at the end... it is not: header_pos
            // points past the truncation), but reads must fail cleanly.
            assert!(r.read_full().is_err());
        }
    }

    // Bit-flipped header area.
    let path2 = dir.join("flip.froot");
    let mut bytes = full.clone();
    let n = bytes.len();
    bytes[n - 20] ^= 0xFF;
    std::fs::write(&path2, &bytes).unwrap();
    match DatasetReader::open(&path2) {
        Err(_) => {}
        Ok(mut r) => {
            let _ = r.read_full(); // must not panic; error or garbage-free data
        }
    }

    // Wrong magic.
    let path3 = dir.join("magic.froot");
    let mut bytes = full;
    bytes[0] ^= 0xFF;
    std::fs::write(&path3, &bytes).unwrap();
    assert!(DatasetReader::open(&path3).is_err());
}

/// Malformed queries fail fast at submit, not in workers.
#[test]
fn malformed_queries_rejected_cleanly() {
    let cluster = Cluster::start(
        ClusterConfig {
            n_workers: 1,
            cache_bytes_per_worker: 64 << 20,
            policy: Policy::AnyPull,
            fetch_delay_per_mib: Duration::ZERO,
            claim_ttl: Duration::from_secs(5),
            straggler: None,
        },
        Backend::Columnar,
    );
    cluster.catalog.register("dy", generate_drellyan(1_000, 73), 500);
    // Unknown dataset.
    assert!(cluster.submit(Query::new(QueryKind::MaxPt, "nope", "muons")).is_err());
    // Unknown list: submit succeeds (partitions exist) but the query
    // errors in workers; claims expire and wait_with_progress times out
    // rather than hanging forever — use cancellation to verify liveness.
    let bad = Query::new(QueryKind::MaxPt, "dy", "jets");
    let h = cluster.submit(bad.clone()).unwrap();
    let res = cluster.wait_with_progress(&h, &bad, |done, _, _| done == 0 && false);
    assert!(res.is_err());
    // Cluster still serves good queries afterwards.
    let good = Query::new(QueryKind::MaxPt, "dy", "muons");
    assert!(cluster.run(&good).is_ok());
    cluster.shutdown();
}
