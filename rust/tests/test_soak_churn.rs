//! Churn soak: the scale-out scheduler under a seeded random failure
//! schedule — workers killed, joined, straggled and crashed mid-claim
//! while solo and fused queries stream through — with **bit-exact**
//! results asserted against an unfailed reference after every query.
//!
//! The schedule is driven by a pinned PCG32 seed (`HEPQ_SOAK_SEED`
//! overrides it), so a CI failure replays exactly. Two tiers:
//!
//! * [`soak_moderate_churn`] — always on: 16 workers, 40 partitions,
//!   8 churn rounds (a few seconds).
//! * [`soak_100_workers_heavy_churn`] — `#[ignore]`d from plain
//!   `cargo test`; CI's `soak` job runs it explicitly: 100+ workers,
//!   heavier kill/join/straggle mix.
//!
//! Invariants checked throughout: every histogram equals the clean-run
//! reference (full `H1` equality including `sum`/`sum2` — the
//! partition-ordered reduction guarantee), and at the end no partial
//! documents or board entries leak, the cluster still answers, and
//! placement telemetry shows affinity actually steered claims.

use hepq::coord::{Cluster, ClusterConfig, Policy};
use hepq::datagen::generate_drellyan;
use hepq::engine::{Backend, Query, QueryKind};
use hepq::hist::H1;
use std::collections::HashMap;
use std::time::Duration;

// ------------------------------------------------------------------ rng

/// PCG32 (Melissa O'Neill's minimal variant): tiny, seedable, and good
/// enough to generate adversarial schedules reproducibly without deps.
struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    fn new(seed: u64) -> Pcg32 {
        let mut r = Pcg32 { state: 0, inc: (seed << 1) | 1 };
        r.next_u32();
        r.state = r.state.wrapping_add(seed);
        r.next_u32();
        r
    }

    fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(6364136223846793005).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Uniform in `0..n` (modulo bias is irrelevant for schedule-mixing).
    fn below(&mut self, n: usize) -> usize {
        (self.next_u32() as usize) % n.max(1)
    }

    fn chance(&mut self, percent: u32) -> bool {
        self.next_u32() % 100 < percent
    }
}

fn soak_seed() -> u64 {
    std::env::var("HEPQ_SOAK_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE)
}

// ---------------------------------------------------------------- harness

const KINDS: [QueryKind; 4] = [
    QueryKind::MaxPt,
    QueryKind::MassPairs,
    QueryKind::FlatHist,
    QueryKind::EtaBest,
];

struct SoakParams {
    n_workers: usize,
    events: usize,
    part_events: usize,
    rounds: usize,
    /// Never kill below this many live workers.
    min_live: usize,
    /// Cap on join churn (total worker slots ever created).
    max_spawns: usize,
}

fn datasets() -> Vec<(&'static str, u64)> {
    vec![("dy_a", 4101), ("dy_b", 4102)]
}

fn churn_config(n_workers: usize) -> ClusterConfig {
    ClusterConfig {
        n_workers,
        cache_bytes_per_worker: 32 << 20,
        policy: Policy::cache_aware(),
        fetch_delay_per_mib: Duration::from_millis(5),
        claim_ttl: Duration::from_secs(30),
        heartbeat_timeout: Duration::from_millis(150),
        affinity_grace: Duration::from_millis(10),
        query_deadline: Duration::from_secs(60),
        speculation_factor: 3.0,
        speculation_min: Duration::from_millis(100),
        ..ClusterConfig::default()
    }
}

/// Clean-run reference histograms for every (dataset, kind) pair, computed
/// on an unfailed two-worker cluster over identical registrations. The
/// partition-ordered reduction makes these bit-equal to any churn run.
fn references(p: &SoakParams) -> HashMap<(String, &'static str), H1> {
    let c = Cluster::start(
        ClusterConfig {
            fetch_delay_per_mib: Duration::ZERO,
            ..churn_config(2)
        },
        Backend::Columnar,
    );
    for (name, seed) in datasets() {
        c.catalog.register(name, generate_drellyan(p.events, seed), p.part_events);
    }
    let mut refs = HashMap::new();
    for (name, _) in datasets() {
        for kind in KINDS {
            let q = Query::new(kind, name, "muons");
            let hist = c.run(&q).expect("reference run").hist;
            refs.insert((name.to_string(), kind.artifact()), hist);
        }
    }
    c.shutdown();
    refs
}

fn run_soak(p: SoakParams) {
    let seed = soak_seed();
    let mut rng = Pcg32::new(seed);
    let refs = references(&p);
    let c = Cluster::start(churn_config(p.n_workers), Backend::Columnar);
    for (name, dseed) in datasets() {
        c.catalog.register(name, generate_drellyan(p.events, dseed), p.part_events);
    }
    let mut spawned = p.n_workers;
    let mut queries_checked = 0usize;
    let mut kills = 0usize;

    for round in 0..p.rounds {
        // Pre-submit churn: join a worker, straggle one, or clear load.
        let live = c.live_worker_ids();
        match rng.below(4) {
            0 if spawned < p.max_spawns => {
                c.spawn_worker();
                spawned += 1;
            }
            1 => {
                let w = live[rng.below(live.len())];
                c.set_handicap(w, Duration::from_millis(50 + rng.below(150) as u64));
            }
            2 => {
                let w = live[rng.below(live.len())];
                c.set_handicap(w, Duration::ZERO);
            }
            _ => {}
        }

        // Submit: a fused group or a burst of solo queries, one dataset.
        let (ds, _) = datasets()[rng.below(datasets().len())];
        let n_queries = 1 + rng.below(3);
        let queries: Vec<Query> = (0..n_queries)
            .map(|_| Query::new(KINDS[rng.below(KINDS.len())], ds, "muons"))
            .collect();
        let fused = n_queries > 1 && rng.chance(50);
        let handles = if fused {
            c.submit_fused(&queries).expect("fused submit")
        } else {
            queries
                .iter()
                .map(|q| c.submit(q.clone()).expect("submit"))
                .collect()
        };

        // Mid-query churn: kill or crash-mid-claim a live worker (keeping
        // a quorum alive so every query can still finish).
        let live = c.live_worker_ids();
        if live.len() > p.min_live {
            match rng.below(3) {
                0 => {
                    let w = live[rng.below(live.len())];
                    c.kill_worker(w);
                    kills += 1;
                }
                1 => {
                    let w = live[rng.below(live.len())];
                    c.inject_abandon(w, 1);
                    kills += 1;
                }
                _ => {}
            }
        }

        for (h, q) in handles.iter().zip(&queries) {
            let res = c.wait(h, q).expect("query under churn");
            let want = &refs[&(q.dataset.clone(), q.kind.artifact())];
            assert_eq!(
                &res.hist, want,
                "round {round} (seed {seed:#x}): {} on {} diverged from the \
                 unfailed reference",
                q.kind.artifact(),
                q.dataset
            );
            queries_checked += 1;
        }

        // Between rounds the cluster must be fully quiescent: every
        // document drained or tombstoned, every board entry cleaned up.
        assert_eq!(c.board_backlog(), 0, "round {round}: board leaked entries");
        assert_eq!(c.pending_docs(), 0, "round {round}: documents leaked");
    }

    // Stable phase: no churn, repeat one query; placement telemetry must
    // show the affinity design working (owners claiming their partitions)
    // and the caches actually being reused.
    for w in c.live_worker_ids() {
        c.set_handicap(w, Duration::ZERO);
    }
    let (ds, _) = datasets()[0];
    let q = Query::new(QueryKind::MaxPt, ds, "muons");
    for _ in 0..3 {
        let res = c.run(&q).expect("stable-phase query");
        assert_eq!(&res.hist, &refs[&(ds.to_string(), q.kind.artifact())]);
    }
    let stats = c.stats();
    let affinity_hits: u64 = stats.iter().map(|s| s.affinity_hits).sum();
    assert!(affinity_hits > 0, "affinity never steered a single claim");
    assert!(
        c.total_cache_hit_rate() > 0.2,
        "cache hit rate {:.2} — placement is not reusing warm workers",
        c.total_cache_hit_rate()
    );
    let placement = c.placement_stats();
    assert_eq!(placement.query_timeouts, 0, "soak queries must never time out");
    // Kills that land while the victim holds a claim surface as failovers;
    // kills of idle workers don't — so recovery counters are reported, not
    // asserted (the bit-exactness above is the real guarantee).
    println!(
        "soak ok (seed {seed:#x}): {queries_checked} queries bit-exact under churn; \
         {kills} kills, {} live of {spawned} spawned; failovers {} specs {} dups {}",
        c.live_worker_ids().len(),
        placement.failovers,
        placement.speculative_reopens,
        placement.duplicate_docs,
    );
    c.shutdown();
}

/// Always-on tier: moderate churn, a few seconds of wall clock.
#[test]
fn soak_moderate_churn() {
    run_soak(SoakParams {
        n_workers: 16,
        events: 40_000,
        part_events: 1_000,
        rounds: 8,
        min_live: 4,
        max_spawns: 24,
    });
}

/// The 100+-worker churn soak the ISSUE demands. Ignored under plain
/// `cargo test` (tens of seconds); CI's `soak` job runs it with
/// `-- --ignored` and a pinned `HEPQ_SOAK_SEED`.
#[test]
#[ignore = "heavy: run explicitly (CI soak job) with --ignored"]
fn soak_100_workers_heavy_churn() {
    run_soak(SoakParams {
        n_workers: 100,
        events: 120_000,
        part_events: 1_000,
        rounds: 25,
        min_live: 8,
        max_spawns: 140,
    });
}
