//! Randomized AGC shape properties: cross-list pair nests, gathers at
//! non-constant indices (guaranteed-OOB and empty-list lanes included),
//! NaN fills and 1–8-point systematic-variation batches — bit-identical
//! across the scalar closures, the chunked kernels, the morsel-parallel
//! driver and the cluster.
//!
//! Comparison discipline (the drivers' documented contracts):
//! - sequential tiers (flat walker, scalar closures, chunked kernels,
//!   thread-1 parallel) agree **wholesale**, running Σw·v moments included
//!   — their accumulators associate additions identically;
//! - split tiers (multi-threaded morsels, cluster partitions) agree on
//!   every bin content, weight count and overflow pocket (dyadic-weight
//!   sums are exactly associative), and any two runs over the *same* split
//!   grid agree wholesale (deterministic ordered merges).

use hepq::columnar::ColumnSet;
use hepq::coord::{Cluster, ClusterConfig, Policy};
use hepq::datagen::generate_ttbar;
use hepq::engine::{Backend, Query};
use hepq::hist::{Hist, Sink, H1};
use hepq::queryir::{self, flat, lower, KernelShape, ParallelCfg};
use hepq::util::rng::Pcg32;
use std::sync::Arc;
use std::time::Duration;

type Bin3 = (usize, f64, f64);
type GroupOut = (H1, Vec<Sink>);

/// Dyadic weights: their sums (and products with integer fill counts) are
/// exact in f64, so bin contents survive any merge order bit-for-bit.
const WEIGHTS: [f64; 8] = [0.5, 0.25, 1.0, 2.0, 0.75, 1.5, 4.0, 1.25];

fn weight_list(k: usize) -> String {
    WEIGHTS[..k].iter().map(|w| format!("{w:?}")).collect::<Vec<_>>().join(", ")
}

fn run_flat(src: &str, cs: &ColumnSet, x: Bin3, y: Bin3) -> GroupOut {
    let prog = queryir::compile(src, &cs.schema).expect("compile");
    let mut h = H1::new(x.0, x.1, x.2);
    let mut aux = prog.make_aux(x, y);
    flat::run_group(&prog, cs, &mut h, &mut aux).expect("flat");
    (h, aux)
}

fn run_compiled(
    src: &str,
    cs: &ColumnSet,
    x: Bin3,
    y: Bin3,
    cfg: Option<ParallelCfg>,
    scalar: bool,
) -> GroupOut {
    let prog = queryir::compile(src, &cs.schema).expect("compile");
    let cp = lower::lower(&prog).expect("lower");
    let mut h = H1::new(x.0, x.1, x.2);
    let mut aux = cp.make_aux(x, y);
    match (scalar, cfg) {
        (true, _) => lower::run_scalar_group(&cp, cs, &mut h, &mut aux).expect("scalar"),
        (false, None) => lower::run_group(&cp, cs, &mut h, &mut aux).expect("chunked"),
        (false, Some(c)) => {
            lower::run_parallel_group(&cp, cs, &mut h, &mut aux, c).expect("parallel")
        }
    }
    (h, aux)
}

fn assert_bitident(a: &GroupOut, b: &GroupOut, what: &str) {
    assert_eq!(a.0, b.0, "{what}: primary");
    assert_eq!(a.1, b.1, "{what}: aux");
}

fn assert_stable_h1(a: &H1, b: &H1, what: &str) {
    assert_eq!(a.bins, b.bins, "{what}: bins");
    assert_eq!(a.count, b.count, "{what}: count");
    assert_eq!(a.underflow, b.underflow, "{what}: underflow");
    assert_eq!(a.overflow, b.overflow, "{what}: overflow");
}

fn assert_stable(a: &GroupOut, b: &GroupOut, what: &str) {
    assert_stable_h1(&a.0, &b.0, what);
    assert_eq!(a.1.len(), b.1.len(), "{what}: sink count");
    for (sa, sb) in a.1.iter().zip(&b.1) {
        assert_eq!(sa.label, sb.label, "{what}");
        let w = format!("{what}/{}", sa.label);
        match (&sa.hist, &sb.hist) {
            (Hist::H1(p), Hist::H1(q)) => assert_stable_h1(p, q, &w),
            (Hist::H2(p), Hist::H2(q)) => {
                assert_eq!(p.bins, q.bins, "{w}: bins");
                assert_eq!(p.out, q.out, "{w}: out");
                assert_eq!(p.count, q.count, "{w}: count");
            }
            (Hist::Profile(p), Hist::Profile(q)) => {
                assert_eq!(p.count, q.count, "{w}: counts");
                assert_eq!(p.under, q.under, "{w}: under");
                assert_eq!(p.over, q.over, "{w}: over");
                assert_eq!(p.total, q.total, "{w}: total");
            }
            _ => panic!("{w}: sink shape mismatch"),
        }
    }
}

/// Cross-list muon×jet pair nests with a randomized cut, an H2 map and a
/// randomized 1–8-point variation batch, swept over the morsel grid.
#[test]
fn cross_list_pairs_survive_every_tier_and_morsel_grid() {
    for trial in 0u64..3 {
        let mut rng = Pcg32::new(0xA6C0 + trial);
        let cut = 20 + rng.below(30);
        let k = 1 + rng.below(8) as usize;
        let src = format!(
            "\
for event in dataset:
    nm = len(event.muons)
    nj = len(event.jets)
    for i in range(nm):
        for j in range(nj):
            m = event.muons[i]
            jet = event.jets[j]
            if jet.pt > {cut}:
                fill(m.pt + jet.pt)
                fill2(m.pt + jet.pt, jet.eta)
                fill_vars(m.pt + jet.pt, {})
",
            weight_list(k)
        );
        let events = 1_500 + 500 * trial as usize;
        let cs = generate_ttbar(events, 6, 9_000 + trial);
        let x: Bin3 = (48 + trial as usize, 0.0, 512.0);
        let y: Bin3 = (24, -4.8, 4.8);

        let prog = queryir::compile(&src, &cs.schema).unwrap();
        let cp = lower::lower(&prog).unwrap();
        assert_eq!(cp.kernel_shape(), Some(KernelShape::Pairs), "trial {trial}");
        assert_eq!(cp.make_aux(x, y).len(), 1 + k, "trial {trial}");

        let reference = run_flat(&src, &cs, x, y);
        assert!(reference.0.total() > 0.0, "trial {trial}: cut ate everything");
        let chunked = run_compiled(&src, &cs, x, y, None, false);
        assert_bitident(&chunked, &reference, "chunked vs flat");
        let scalar = run_compiled(&src, &cs, x, y, None, true);
        assert_bitident(&scalar, &reference, "scalar vs flat");

        for morsel in [1usize, 7, 1024, 0] {
            let mut per_grid: Vec<GroupOut> = Vec::new();
            for threads in [1usize, 2, 8] {
                let cfg = ParallelCfg { threads, morsel_events: morsel };
                let out = run_compiled(&src, &cs, x, y, Some(cfg), false);
                let what = format!("trial {trial} morsel {morsel} threads {threads}");
                if threads == 1 {
                    assert_bitident(&out, &reference, &what);
                } else {
                    assert_stable(&out, &reference, &what);
                    per_grid.push(out);
                }
            }
            // Same morsel grid ⇒ same association ⇒ wholesale identity
            // regardless of how many threads pulled the morsels.
            assert_bitident(
                &per_grid[0],
                &per_grid[1],
                &format!("trial {trial} morsel {morsel} thread counts"),
            );
        }
    }
}

/// Gathers at non-constant indices: empty-list lanes fall out of the
/// guard, guarded last/first-element reads agree across tiers, and the
/// unguarded read one past the end errors in every compiled tier with
/// the scalar error text.
#[test]
fn dynamic_gathers_handle_empty_lists_and_oob() {
    for trial in 0u64..3 {
        let mut rng = Pcg32::new(0xD9A + trial);
        let guard = rng.below(2); // n > 0 or n > 1
        let src = format!(
            "\
for event in dataset:
    n = len(event.muons)
    if n > {guard}:
        fill(event.muons[n - 1].pt)
        fill2(event.muons[n - 1].pt, event.muons[0].eta)
"
        );
        let events = 2_000 + 300 * trial as usize;
        let cs = generate_ttbar(events, 5, 7_700 + trial);
        let x: Bin3 = (64, 0.0, 128.0);
        let y: Bin3 = (16, -4.0, 4.0);

        let reference = run_flat(&src, &cs, x, y);
        // poisson(1.1) muons: a third of events have an empty list, so the
        // guard must really be dropping lanes.
        assert!(reference.0.total() > 0.0, "trial {trial}");
        assert!(reference.0.total() < events as f64, "trial {trial}: no empty lanes?");

        let chunked = run_compiled(&src, &cs, x, y, None, false);
        assert_bitident(&chunked, &reference, "chunked vs flat");
        let scalar = run_compiled(&src, &cs, x, y, None, true);
        assert_bitident(&scalar, &reference, "scalar vs flat");
        let cfg = ParallelCfg { threads: 4, morsel_events: 311 };
        let par = run_compiled(&src, &cs, x, y, Some(cfg), false);
        assert_stable(&par, &reference, "parallel vs flat");

        // Guaranteed out-of-bounds: `muons[n]` on the last event reads past
        // the global content array in every compiled tier.
        let oob = "\
for event in dataset:
    n = len(event.muons)
    fill(event.muons[n].pt)
";
        let prog = queryir::compile(oob, &cs.schema).unwrap();
        let cp = lower::lower(&prog).unwrap();
        let mut h = H1::new(8, 0.0, 128.0);
        let e = lower::run_group(&cp, &cs, &mut h, &mut []).unwrap_err();
        assert!(e.contains("out of bounds"), "chunked: {e}");
        let mut h = H1::new(8, 0.0, 128.0);
        let e = lower::run_scalar_group(&cp, &cs, &mut h, &mut []).unwrap_err();
        assert!(e.contains("out of bounds"), "scalar: {e}");
        let mut h = H1::new(8, 0.0, 128.0);
        let e = lower::run_parallel_group(&cp, &cs, &mut h, &mut [], cfg).unwrap_err();
        assert!(e.contains("out of bounds"), "parallel: {e}");
        let mut h = H1::new(8, 0.0, 128.0);
        let e = flat::run_group(&prog, &cs, &mut h, &mut []).unwrap_err();
        assert!(e.contains("out of bounds"), "flat: {e}");
    }
}

/// NaN fill values (sqrt of a negative) are skipped by every sink shape,
/// identically in every tier.
#[test]
fn nan_lanes_are_skipped_identically() {
    let src = "\
for event in dataset:
    for muon in event.muons:
        fill(sqrt(muon.eta) * 32)
        fill2(sqrt(muon.eta) * 32, muon.pt)
        fill_vars(sqrt(muon.eta) * 32, 0.5, 1.0, 2.0)
";
    let cs = generate_ttbar(2_500, 5, 515);
    let x: Bin3 = (32, 0.0, 64.0);
    let y: Bin3 = (16, 0.0, 128.0);

    let reference = run_flat(src, &cs, x, y);
    // Roughly half the etas are negative: NaN lanes must exist and be
    // dropped, not binned somewhere.
    assert!(reference.0.total() > 0.0);
    let mut plain = H1::new(32, 0.0, 64.0);
    queryir::run_transformed(
        "for event in dataset:\n    for muon in event.muons:\n        fill(muon.pt)\n",
        &cs,
        &mut plain,
    )
    .unwrap();
    assert!(reference.0.total() < plain.total(), "no NaN lanes were dropped");
    for s in &reference.1 {
        assert_eq!(s.hist.total(), reference.0.total() * weight_of(&s.label), "{}", s.label);
    }

    let chunked = run_compiled(src, &cs, x, y, None, false);
    assert_bitident(&chunked, &reference, "chunked vs flat");
    let scalar = run_compiled(src, &cs, x, y, None, true);
    assert_bitident(&scalar, &reference, "scalar vs flat");
    let cfg = ParallelCfg { threads: 3, morsel_events: 129 };
    let par = run_compiled(src, &cs, x, y, Some(cfg), false);
    assert_stable(&par, &reference, "parallel vs flat");
}

/// Sink totals in `nan_lanes_are_skipped_identically`: the H2 sees weight
/// 1 per surviving lane; the variations see their batch weight.
fn weight_of(label: &str) -> f64 {
    match label.rsplit('.').next().and_then(|k| k.parse::<usize>().ok()) {
        Some(0) if label.starts_with("var#") => 0.5,
        Some(1) if label.starts_with("var#") => 1.0,
        Some(2) if label.starts_with("var#") => 2.0,
        _ => 1.0,
    }
}

/// Variation batches from 1 to 8 points: one sink per weight, labeled by
/// site and ordinal, each total exactly `w × (primary total)`.
#[test]
fn variation_batches_scale_exactly_1_to_8() {
    let cs = generate_ttbar(2_000, 5, 616);
    let x: Bin3 = (64, 0.0, 128.0);
    let y: Bin3 = (16, 0.0, 1.0);
    for k in 1..=8usize {
        let src = format!(
            "\
for event in dataset:
    for muon in event.muons:
        if muon.pt > 22:
            fill(muon.pt)
            fill_vars(muon.pt, {})
",
            weight_list(k)
        );
        let reference = run_flat(&src, &cs, x, y);
        assert_eq!(reference.1.len(), k, "k={k}");
        let n = reference.0.total();
        assert!(n > 0.0, "k={k}");
        for (i, s) in reference.1.iter().enumerate() {
            assert!(s.label.starts_with("var#"), "k={k}: {}", s.label);
            assert!(s.label.ends_with(&format!(".{i}")), "k={k}: {}", s.label);
            // Dyadic weight × integer fill count: exact in f64.
            assert_eq!(s.hist.total(), WEIGHTS[i] * n, "k={k} var {i}");
        }
        let chunked = run_compiled(&src, &cs, x, y, None, false);
        assert_bitident(&chunked, &reference, "chunked vs flat");
        let cfg = ParallelCfg { threads: 2, morsel_events: 513 };
        let par = run_compiled(&src, &cs, x, y, Some(cfg), false);
        assert_stable(&par, &reference, "parallel vs flat");
    }
}

/// The distributed tier: the same aux-rich query over two different
/// partition grids agrees on the associative parts with the single-scan
/// reference, and each grid is wholesale-reproducible run to run.
#[test]
fn cluster_splits_agree_and_reproduce() {
    let src = "\
for event in dataset:
    n = len(event.muons)
    if n > 0:
        fill(event.muons[n - 1].pt)
        fill2(event.muons[n - 1].pt, event.muons[0].eta)
        profile(event.muons[n - 1].pt, n)
        fill_vars(event.muons[n - 1].pt, 0.5, 1.0, 2.0)
";
    let events = 6_000;
    let seed = 717;
    let cs = generate_ttbar(events, 5, seed);
    let x: Bin3 = (64, 0.0, 128.0);
    let y: Bin3 = (16, -4.0, 4.0);
    let reference = run_flat(src, &cs, x, y);

    let cluster = Arc::new(Cluster::start(
        ClusterConfig {
            n_workers: 3,
            cache_bytes_per_worker: 64 << 20,
            policy: Policy::AnyPull,
            fetch_delay_per_mib: Duration::ZERO,
            claim_ttl: Duration::from_secs(10),
            ..ClusterConfig::default()
        },
        Backend::compiled(),
    ));
    cluster.catalog.register("tt_a", generate_ttbar(events, 5, seed), 397);
    cluster.catalog.register("tt_b", generate_ttbar(events, 5, seed), 1_500);

    for ds in ["tt_a", "tt_b"] {
        let q = Query::from_source(src, ds)
            .with_binning(x.0, x.1, x.2)
            .with_y_binning(y.0, y.1, y.2);
        let r1 = cluster.run(&q).unwrap();
        assert_stable(&(r1.hist.clone(), r1.aux.clone()), &reference, ds);
        let labels: Vec<&str> = r1.aux.iter().map(|s| s.label.as_str()).collect();
        assert_eq!(labels.len(), 5, "{ds}");
        assert!(labels[0].starts_with("h2#"), "{ds}: {labels:?}");
        assert!(labels[1].starts_with("prof#"), "{ds}: {labels:?}");
        assert!(labels[2].starts_with("var#"), "{ds}: {labels:?}");
        // Same partition grid ⇒ same ordered merge ⇒ wholesale identity.
        let r2 = cluster.run(&q).unwrap();
        assert_eq!(r2.hist, r1.hist, "{ds}: repeat primary");
        assert_eq!(r2.aux, r1.aux, "{ds}: repeat aux");
    }
    cluster.shutdown();
}
