//! Equivalence properties of the event-level and pair-loop chunked
//! kernels (PR 5) and the zero-allocation scratch pool.
//!
//! The guarantees under test:
//!   * **event kernels** — loop-free per-event bodies over `event.met`,
//!     `len(...)` cuts, inlined assignments and leading-object loads — are
//!     bit-identical to the scalar closure loop (bins, under/overflow,
//!     count, sum, sum2) across randomized program shapes, NaN-producing
//!     expressions, weighted fills and binnings;
//!   * **pair kernels** — `range(len(l))` nests, the paper's dimuon-mass
//!     shape — are bit-identical to the scalar closure nest, cuts and
//!     weights included, with empty/singleton lists handled by the same
//!     enumeration;
//!   * both compose with morsel-driven parallelism across the grid
//!     morsel ∈ {1, 7, 1024, whole} × threads ∈ {1, 2, 8};
//!   * a reused [`KernelScratch`] stops allocating after the first morsel
//!     warms it — the zero-allocation-per-morsel regression guard.

use hepq::datagen::generate_drellyan;
use hepq::hist::H1;
use hepq::queryir::lower::{self, KernelScratch, ParallelCfg};
use hepq::queryir::{self, table3, KernelShape};
use hepq::util::propkit::{check, Config, Gen};

/// Morsel merges reorder only the moment additions.
fn assert_morsel_equiv(seq: &H1, par: &H1, what: &str) {
    assert_eq!(seq.bins, par.bins, "{what}: bins");
    assert_eq!(seq.underflow, par.underflow, "{what}: underflow");
    assert_eq!(seq.overflow, par.overflow, "{what}: overflow");
    assert_eq!(seq.count, par.count, "{what}: count");
    for (name, a, b) in [("sum", seq.sum, par.sum), ("sum2", seq.sum2, par.sum2)] {
        let tol = 1e-9 * a.abs().max(b.abs()).max(1.0);
        assert!(
            (a - b).abs() <= tol,
            "{what}: {name} {a} vs {b} beyond merge tolerance"
        );
    }
}

/// Random loop-free per-event body: event leaves, `len()` cuts, inlined
/// assignments, leading-object loads, NaN-producing values, weights.
fn random_event_program(g: &mut Gen) -> String {
    let t = g.usize_to(60) as f64 - 5.0;
    let k = g.usize_to(3);
    let w = ["", ", 0.5", ", event.met * 0.25"][g.usize_to(2)];
    match g.usize_to(6) {
        0 => format!("for event in dataset:\n    fill(event.met{w})\n"),
        1 => format!(
            "for event in dataset:\n    if event.met > {t}:\n        fill(event.met{w})\n"
        ),
        2 => format!(
            "for event in dataset:\n    if len(event.muons) >= {k}:\n        \
             fill(event.met{w})\n    else:\n        fill(len(event.muons))\n"
        ),
        3 => format!(
            "for event in dataset:\n    x = event.met * 0.5 + 1\n    \
             if x > {t} and len(event.muons) > 0:\n        fill(x{w})\n"
        ),
        // NaN-producing fill values (sqrt/log of negatives) are skipped
        // identically on both paths.
        4 => format!("for event in dataset:\n    fill(sqrt(event.met - {t}){w})\n"),
        5 => format!(
            "for event in dataset:\n    m = event.muons[0]\n    \
             if len(event.muons) > 0:\n        fill(m.pt{w})\n"
        ),
        _ => format!(
            "for event in dataset:\n    if not event.met > {t}:\n        \
             fill(log(event.met - 10))\n    fill(event.met, 0.5)\n"
        ),
    }
}

/// Random `range(len)` pair body: the canonical `(i, i+1)` nest or the
/// full cross product, with cuts, weights and NaN-able values.
fn random_pair_program(g: &mut Gen) -> String {
    let t = g.usize_to(80) as f64;
    let inner = match g.usize_to(4) {
        0 => "mass = sqrt(2 * a.pt * b.pt * (cosh(a.eta - b.eta) - cos(a.phi - b.phi)))\n\
              \x20           fill(mass)"
            .to_string(),
        1 => format!(
            "if a.pt + b.pt > {t}:\n                fill(a.pt + b.pt, 0.5)"
        ),
        2 => "fill(sqrt(a.eta - b.eta))".to_string(), // NaN for half the pairs
        3 => "if a.eta * b.eta < 0:\n                fill(a.pt + b.pt)\n\
              \x20           else:\n                fill(a.pt - b.pt, 0.25)"
            .to_string(),
        _ => "fill(log(a.eta * b.eta), a.pt * 0.125)".to_string(),
    };
    let j_range = if g.usize_to(3) == 0 { "range(n)" } else { "range(i + 1, n)" };
    format!(
        "for event in dataset:\n    n = len(event.muons)\n    for i in range(n):\n        \
         for j in {j_range}:\n            a = event.muons[i]\n            \
         b = event.muons[j]\n            {inner}\n"
    )
}

/// Randomized event bodies: every generated shape lowers to the event
/// kernel and agrees with the scalar closure loop to the last bit over
/// random samples and binnings (empty and singleton muon lists occur
/// naturally in the generated events).
#[test]
fn prop_random_event_bodies_chunked_bit_identical() {
    let cfg = Config {
        cases: 24,
        ..Config::default()
    };
    check(
        "event-bodies-chunked-bit-identical",
        &cfg,
        |g| {
            (
                random_event_program(g),
                1 + g.usize_to(2_500),
                g.rng.next_u64(),
            )
        },
        |(src, n, seed)| {
            let cs = generate_drellyan(*n, *seed);
            let prog = queryir::compile(src, &cs.schema)?;
            let cp = lower::lower(&prog)?;
            if cp.kernel_shape() != Some(KernelShape::Events) {
                return Err(format!("did not lower to the event kernel:\n{src}"));
            }
            for (n_bins, lo, hi) in [(64, -8.0, 120.0), (9, 3.0, 40.0)] {
                let mut chunked = H1::new(n_bins, lo, hi);
                lower::run(&cp, &cs, &mut chunked)?;
                let mut scalar = H1::new(n_bins, lo, hi);
                lower::run_scalar(&cp, &cs, &mut scalar)?;
                if chunked != scalar {
                    return Err(format!(
                        "event kernel != scalar on {n_bins}x[{lo},{hi}) for:\n{src}"
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Randomized pair bodies: every generated shape lowers to the pair
/// kernel and agrees with the scalar closure nest to the last bit —
/// pair order, cuts, weights and NaN semantics included.
#[test]
fn prop_random_pair_bodies_chunked_bit_identical() {
    let cfg = Config {
        cases: 18,
        ..Config::default()
    };
    check(
        "pair-bodies-chunked-bit-identical",
        &cfg,
        |g| {
            (
                random_pair_program(g),
                1 + g.usize_to(1_200),
                g.rng.next_u64(),
            )
        },
        |(src, n, seed)| {
            let cs = generate_drellyan(*n, *seed);
            let prog = queryir::compile(src, &cs.schema)?;
            let cp = lower::lower(&prog)?;
            if cp.kernel_shape() != Some(KernelShape::Pairs) {
                return Err(format!("did not lower to the pair kernel:\n{src}"));
            }
            for (n_bins, lo, hi) in [(64, -8.0, 160.0), (11, 20.0, 90.0)] {
                let mut chunked = H1::new(n_bins, lo, hi);
                lower::run(&cp, &cs, &mut chunked)?;
                let mut scalar = H1::new(n_bins, lo, hi);
                lower::run_scalar(&cp, &cs, &mut scalar)?;
                if chunked != scalar {
                    return Err(format!(
                        "pair kernel != scalar on {n_bins}x[{lo},{hi}) for:\n{src}"
                    ));
                }
            }
            Ok(())
        },
    );
}

/// The ISSUE grid — morsel ∈ {1, 7, 1024, whole} × threads ∈ {1, 2, 8} —
/// over one body per new kernel family (dyadic weights, so bins and count
/// are exact under any merge association).
#[test]
fn event_and_pair_morsel_grid_matches_sequential() {
    const N: usize = 5_000;
    let cs = generate_drellyan(N, 171);
    let event_cut = "\
for event in dataset:
    if event.met > 15 and len(event.muons) >= 2:
        fill(event.met, 0.5)
";
    let leading = "\
for event in dataset:
    m = event.muons[0]
    if len(event.muons) > 0:
        fill(m.pt)
";
    let pair_cut = "\
for event in dataset:
    n = len(event.muons)
    for i in range(n):
        for j in range(i + 1, n):
            a = event.muons[i]
            b = event.muons[j]
            if a.eta * b.eta < 0:
                fill(a.pt + b.pt, 0.5)
";
    for (name, src, shape) in [
        ("event_cut", event_cut, KernelShape::Events),
        ("leading", leading, KernelShape::Events),
        ("mass_pairs", table3::MASS_PAIRS, KernelShape::Pairs),
        ("pair_cut", pair_cut, KernelShape::Pairs),
    ] {
        let prog = queryir::compile(src, &cs.schema).unwrap();
        let cp = lower::lower(&prog).unwrap();
        assert_eq!(cp.kernel_shape(), Some(shape), "{name}");
        let mut seq = H1::new(64, 0.0, 128.0);
        lower::run(&cp, &cs, &mut seq).unwrap();
        for morsel_events in [1usize, 7, 1024, N] {
            for threads in [1usize, 2, 8] {
                let mut par = H1::new(64, 0.0, 128.0);
                let cfg = ParallelCfg {
                    threads,
                    morsel_events,
                };
                lower::run_parallel(&cp, &cs, &mut par, cfg).unwrap();
                assert_morsel_equiv(
                    &seq,
                    &par,
                    &format!("{name} morsel={morsel_events} threads={threads}"),
                );
            }
        }
    }
}

/// Reusing one [`KernelScratch`] across every morsel of a partition run
/// performs no pool growth after the first morsel — for all three kernel
/// families and the scalar fallback — while staying exact on bins/count.
#[test]
fn scratch_reuse_is_allocation_free_after_warmup() {
    let cs = generate_drellyan(6_000, 172);
    let event_src = "\
for event in dataset:
    if event.met > 15:
        fill(event.met)
";
    for (name, src) in [
        ("items", table3::MUON_PT),
        ("events", event_src),
        ("pairs", table3::MASS_PAIRS),
        ("scalar", table3::MAX_PT),
    ] {
        let prog = queryir::compile(src, &cs.schema).unwrap();
        let cp = lower::lower(&prog).unwrap();
        let mut whole = H1::new(64, 0.0, 128.0);
        lower::run(&cp, &cs, &mut whole).unwrap();
        let mut scratch = KernelScratch::new();
        let mut tiled = H1::new(64, 0.0, 128.0);
        lower::run_range_scratch(&cp, &cs.range(0, 750), &mut tiled, &mut scratch).unwrap();
        let warmed = scratch.allocation_events();
        assert!(warmed > 0, "{name}: first morsel should warm the pool");
        let mut ev = 750;
        while ev < cs.n_events {
            let hi = (ev + 750).min(cs.n_events);
            lower::run_range_scratch(&cp, &cs.range(ev, hi), &mut tiled, &mut scratch).unwrap();
            ev = hi;
        }
        assert_eq!(
            scratch.allocation_events(),
            warmed,
            "{name}: kernel scratch grew after the first morsel"
        );
        assert_eq!(whole.bins, tiled.bins, "{name}");
        assert_eq!(whole.count, tiled.count, "{name}");
    }
}

/// Tiny partitions — empty lists, singleton lists, fewer events than one
/// chunk — go through the same kernels and stay bit-identical.
#[test]
fn tiny_partitions_and_empty_lists_are_exact() {
    for n in [1usize, 2, 3, 17] {
        for seed in [1u64, 9, 33] {
            let cs = generate_drellyan(n, seed);
            for src in [table3::MASS_PAIRS, table3::MUON_PT] {
                let prog = queryir::compile(src, &cs.schema).unwrap();
                let cp = lower::lower(&prog).unwrap();
                let mut a = H1::new(16, 0.0, 128.0);
                lower::run(&cp, &cs, &mut a).unwrap();
                let mut b = H1::new(16, 0.0, 128.0);
                lower::run_scalar(&cp, &cs, &mut b).unwrap();
                assert_eq!(a, b, "n={n} seed={seed} {src}");
            }
        }
    }
}
