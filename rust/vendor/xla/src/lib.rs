//! Stub of the XLA/PJRT binding API surface that `hepq`'s `pjrt` feature
//! compiles against.
//!
//! The real backend needs an XLA binding crate with native XLA libraries,
//! which cannot be vendored here. This stub keeps `--features pjrt` building
//! (and the rest of the crate honest about the API boundary) while failing
//! *at runtime*, at client construction, with a clear message. To run real
//! artifacts, replace the `xla` path dependency in the workspace manifest
//! with an actual binding (e.g. a PJRT C-API wrapper) exposing this surface.

use std::fmt;

#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable() -> Error {
    Error(
        "XLA/PJRT runtime not available: this build links the in-tree API stub. \
         Point the `xla` dependency at a real XLA binding to execute artifacts."
            .to_string(),
    )
}

/// A host literal (stub: holds nothing).
#[derive(Debug, Clone)]
pub struct Literal;

impl Literal {
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn to_tuple1(&self) -> Result<Literal, Error> {
        Err(unavailable())
    }

    pub fn to_vec<T: Copy>(&self) -> Result<Vec<T>, Error> {
        Err(unavailable())
    }
}

/// Parsed HLO module (stub).
#[derive(Debug, Clone)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        Err(unavailable())
    }
}

/// An XLA computation (stub).
#[derive(Debug, Clone)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer handle (stub).
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(unavailable())
    }
}

/// Compiled executable (stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable())
    }
}

/// PJRT client (stub: construction always fails, so dependents degrade
/// gracefully before ever reaching execution).
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(unavailable())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_at_client_construction() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.0.contains("stub"));
        assert!(HloModuleProto::from_text_file("/nope").is_err());
    }
}
