#!/usr/bin/env python3
"""Docs link checker (stdlib only; CI runs it on every push).

Two invariants, both cheap and both high-value for a repo whose docs are
the operator manual:

1. Every relative markdown link in README.md and docs/*.md resolves to a
   real file (so `docs/SERVER_PROTOCOL.md` can never silently 404).
2. Every `rust/src/...`, `rust/tests/...`, `rust/benches/...` or
   `python/...` path *named* in those documents exists — module docs move,
   files get renamed, and stale path references are the classic way a
   protocol manual rots.

Exit status: 0 clean, 1 with a per-problem report on stderr.
"""

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

# Docs that must exist — the glob below silently skips a deleted file, so
# the operator-manual set is pinned here.
REQUIRED_DOCS = [
    "README.md",
    "docs/ARCHITECTURE.md",
    "docs/QUERY_LANGUAGE.md",
    "docs/SERVER_PROTOCOL.md",
    "docs/OBSERVABILITY.md",
]

# Relative markdown links: [text](target). Skips http(s), mailto, and
# pure intra-page anchors.
MD_LINK = re.compile(r"\[[^\]]*\]\(([^)#\s]+)(?:#[^)\s]*)?\)")

# Repo paths named in prose or code spans. A path ends before a character
# that cannot be part of one (backtick, quote, space, paren...). Trailing
# `::item` qualifiers on rust paths are stripped.
REPO_PATH = re.compile(
    r"\b((?:rust/(?:src|tests|benches|vendor)|python|tools|docs|\.github)"
    r"/[A-Za-z0-9_./-]+)"
)


def doc_files():
    yield ROOT / "README.md"
    yield from sorted((ROOT / "docs").glob("*.md"))


def check_file(doc: Path):
    problems = []
    text = doc.read_text(encoding="utf-8")
    rel = doc.relative_to(ROOT)

    for m in MD_LINK.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        # Badge links like ../../actions/... point outside the repo into
        # the forge UI; not a file to check.
        if target.startswith("../"):
            continue
        resolved = (doc.parent / target).resolve()
        if not resolved.exists():
            problems.append(f"{rel}: broken link -> {target}")

    for m in REPO_PATH.finditer(text):
        path = m.group(1).rstrip(".,;:")
        # `rust/src/server/` style directory references end with /.
        candidate = ROOT / path
        if candidate.exists():
            continue
        # Prose sometimes names a file without its extension-bearing
        # suffix being a real path (e.g. "rust/src/queryir/lower.rs
        # (canonical/fingerprint)") — the regex already stops at the
        # space, so anything left unresolved is a genuine stale path.
        problems.append(f"{rel}: stale repo path -> {path}")

    return problems


def main() -> int:
    all_problems = []
    for req in REQUIRED_DOCS:
        if not (ROOT / req).exists():
            all_problems.append(f"missing expected doc: {req}")
    for doc in doc_files():
        if not doc.exists():
            all_problems.append(f"missing expected doc: {doc.relative_to(ROOT)}")
            continue
        all_problems.extend(check_file(doc))
    if all_problems:
        print("doc link check FAILED:", file=sys.stderr)
        for p in all_problems:
            print(f"  {p}", file=sys.stderr)
        return 1
    n = len(list(doc_files()))
    print(f"doc link check OK ({n} documents)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
