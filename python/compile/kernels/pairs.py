"""L1 Pallas kernels: distinct-pair loops fused with histogram fill.

Table 3's pair functions:
  * ``p_T sum of pairs`` — s = pt_i + pt_j over distinct pairs i < j;
  * ``mass of pairs``    — m = sqrt(2 pt_i pt_j (cosh(eta_i - eta_j)
                                               - cos(phi_i - phi_j))).

The paper's nested ``for i / for j in range(i+1, n)`` loops become a dense
masked K x K upper-triangle tensor per event block — the TPU replacement for
GPU-style per-thread pair iteration: K is small (8), so the [block, K, K]
tensor is built in VMEM, masked with an upper-triangle iota, histogrammed
with the one-hot contraction and discarded without ever touching HBM.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .shapes import NBINS
from .hist import _hist_block


def _pair_mask(mask):
    """[b, K] validity -> [b, K, K] distinct upper-triangle pair validity."""
    b, k = mask.shape
    mi = mask[:, :, None] & mask[:, None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (b, k, k), 1)
    jj = jax.lax.broadcasted_iota(jnp.int32, (b, k, k), 2)
    return mi & (ii < jj)


def _ptsum_kernel(pt_ref, m_ref, lo_ref, hi_ref, o_ref, *, nbins):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    pt = pt_ref[...]
    pmask = _pair_mask(m_ref[...] != 0)
    s = pt[:, :, None] + pt[:, None, :]          # [b, K, K]
    o_ref[...] += _hist_block(
        s.reshape(-1), pmask.reshape(-1), lo_ref[0], hi_ref[0], nbins
    )


def _mass_kernel(pt_ref, eta_ref, phi_ref, m_ref, lo_ref, hi_ref, o_ref, *, nbins):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    pt, eta, phi = pt_ref[...], eta_ref[...], phi_ref[...]
    pmask = _pair_mask(m_ref[...] != 0)
    deta = eta[:, :, None] - eta[:, None, :]
    dphi = phi[:, :, None] - phi[:, None, :]
    ptij = pt[:, :, None] * pt[:, None, :]
    m2 = 2.0 * ptij * (jnp.cosh(deta) - jnp.cos(dphi))
    mass = jnp.sqrt(jnp.maximum(m2, 0.0))
    o_ref[...] += _hist_block(
        mass.reshape(-1), pmask.reshape(-1), lo_ref[0], hi_ref[0], nbins
    )


def _call_pair_kernel(kernel, arrays, lo, hi, *, block, nbins):
    n, k = arrays[0].shape
    assert n % block == 0, f"N={n} not a multiple of block={block}"
    grid = n // block
    in_specs = [pl.BlockSpec((block, k), lambda i: (i, 0)) for _ in arrays] + [
        pl.BlockSpec((1,), lambda i: (0,)),
        pl.BlockSpec((1,), lambda i: (0,)),
    ]
    return pl.pallas_call(
        functools.partial(kernel, nbins=nbins),
        grid=(grid,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((nbins + 2,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((nbins + 2,), jnp.float32),
        interpret=True,
    )(*arrays, lo, hi)


@functools.partial(jax.jit, static_argnames=("block", "nbins"))
def ptsum_pairs_hist(pt, mask, lo, hi, *, block=2048, nbins=NBINS):
    """Histogram of pt_i + pt_j over distinct muon pairs per event."""
    return _call_pair_kernel(_ptsum_kernel, [pt, mask], lo, hi, block=block, nbins=nbins)


@functools.partial(jax.jit, static_argnames=("block", "nbins"))
def mass_pairs_hist(pt, eta, phi, mask, lo, hi, *, block=2048, nbins=NBINS):
    """Histogram of the dimuon invariant mass over distinct pairs."""
    return _call_pair_kernel(
        _mass_kernel, [pt, eta, phi, mask], lo, hi, block=block, nbins=nbins
    )
