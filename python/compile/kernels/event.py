"""L1 Pallas kernels: per-event reductions fused with histogram fill.

Table 3's first two analysis functions:
  * ``max p_T``      — per-event maximum over the muon list;
  * ``eta of best``  — eta of the highest-p_T muon (maximize one attribute,
                       plot another).

The paper's per-event Python loops become masked row-reductions over padded
[events, K] tiles; the per-event scalar then feeds the same one-hot
histogram contraction as `hist.py`, all inside one kernel so nothing but
the [NBINS+2] accumulator leaves VMEM.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .shapes import NBINS
from .hist import _hist_block

# Python float literal (a jnp scalar would be captured as a pallas constant).
_NEG = -3.0e38


def _max_pt_kernel(pt_ref, m_ref, lo_ref, hi_ref, o_ref, *, nbins):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    mask = m_ref[...] != 0
    pt = jnp.where(mask, pt_ref[...], _NEG)
    ev_max = jnp.max(pt, axis=1)                 # [block]
    ev_has = jnp.any(mask, axis=1)               # paper: fill only if >=1 muon
    o_ref[...] += _hist_block(ev_max, ev_has, lo_ref[0], hi_ref[0], nbins)


def _eta_best_kernel(pt_ref, eta_ref, m_ref, lo_ref, hi_ref, o_ref, *, nbins):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    mask = m_ref[...] != 0
    pt = jnp.where(mask, pt_ref[...], _NEG)
    # argmax picks the first maximal lane — same as the paper's strict `>`
    # update rule scanning left to right.
    best = jnp.argmax(pt, axis=1)                # [block]
    eta = jnp.take_along_axis(eta_ref[...], best[:, None], axis=1)[:, 0]
    ev_has = jnp.any(mask, axis=1)
    o_ref[...] += _hist_block(eta, ev_has, lo_ref[0], hi_ref[0], nbins)


def _call_event_kernel(kernel, arrays, lo, hi, *, block, nbins):
    n, k = arrays[0].shape
    assert n % block == 0, f"N={n} not a multiple of block={block}"
    grid = n // block
    in_specs = [pl.BlockSpec((block, k), lambda i: (i, 0)) for _ in arrays] + [
        pl.BlockSpec((1,), lambda i: (0,)),
        pl.BlockSpec((1,), lambda i: (0,)),
    ]
    return pl.pallas_call(
        functools.partial(kernel, nbins=nbins),
        grid=(grid,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((nbins + 2,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((nbins + 2,), jnp.float32),
        interpret=True,
    )(*arrays, lo, hi)


@functools.partial(jax.jit, static_argnames=("block", "nbins"))
def max_pt_hist(pt, mask, lo, hi, *, block=2048, nbins=NBINS):
    """Histogram of per-event max pt. pt/mask: [N, K]; lo/hi: f32[1]."""
    return _call_event_kernel(_max_pt_kernel, [pt, mask], lo, hi, block=block, nbins=nbins)


@functools.partial(jax.jit, static_argnames=("block", "nbins"))
def eta_best_hist(pt, eta, mask, lo, hi, *, block=2048, nbins=NBINS):
    """Histogram of eta of the highest-pt muon per event."""
    return _call_event_kernel(
        _eta_best_kernel, [pt, eta, mask], lo, hi, block=block, nbins=nbins
    )
