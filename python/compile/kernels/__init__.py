"""Layer-1 Pallas kernels for hepq.

Every kernel accumulates a partial histogram of shape [NBINS + 2]
(slot 0 = underflow, slots 1..NBINS = in-range bins, slot NBINS+1 =
overflow) over a partition of events, fusing the physics computation with
the histogram fill so pair tensors never round-trip through HBM.

Kernels are lowered with ``interpret=True``: the CPU PJRT plugin cannot run
Mosaic custom-calls, so interpret mode (which lowers to plain HLO) is both
the correctness path and the artifact path on this testbed. The BlockSpec
structure is still written for TPU: the event axis is tiled so each block's
working set fits VMEM (see DESIGN.md section Hardware-Adaptation).
"""

from .shapes import PartitionSpec, DEFAULT_SPEC, NBINS
from . import hist, event, pairs, ref

__all__ = ["PartitionSpec", "DEFAULT_SPEC", "NBINS", "hist", "event", "pairs", "ref"]
