"""Static partition shapes shared by L1 kernels, L2 query graphs and the
Rust runtime.

PJRT executables have fixed input shapes, so the coordinator pads every
partition to exactly these sizes (trailing events get zero-length particle
lists by repeating the last offset). The numbers are chosen so one block of
every kernel fits comfortably in a TPU core's ~16 MiB VMEM — the footprint
table lives in DESIGN.md.
"""

from dataclasses import dataclass

#: In-range histogram bins baked into every artifact. Slot layout of kernel
#: output: [underflow, bins..., overflow] → NBINS + 2 slots.
NBINS = 64


@dataclass(frozen=True)
class PartitionSpec:
    """Shapes of one padded partition."""

    n_events: int = 16384   #: events per partition (padded)
    k_max: int = 8          #: max particles per event after padding
    content_cap: int = 131072  #: capacity of each content array (= 8 * n_events)
    block_events: int = 2048   #: events per Pallas grid step

    @property
    def n_offsets(self) -> int:
        return self.n_events + 1

    @property
    def hist_slots(self) -> int:
        return NBINS + 2

    def vmem_block_bytes(self, n_attrs: int) -> int:
        """Estimated VMEM working set of one pair-kernel block: padded
        attribute tiles + the KxK pair tensor + the histogram accumulator."""
        tile = self.block_events * self.k_max * 4 * n_attrs
        pair = self.block_events * self.k_max * self.k_max * 4
        hist = self.hist_slots * 4
        return tile + pair + hist


#: Production artifact shapes (what `make artifacts` bakes).
DEFAULT_SPEC = PartitionSpec()

#: Small shapes for fast pytest/hypothesis sweeps.
TEST_SPEC = PartitionSpec(n_events=32, k_max=4, content_cap=256, block_events=8)
