"""L1 Pallas kernel: masked histogram fill via one-hot contraction.

The paper's hot loop is ``fill_histogram(value)`` executed hundreds of
millions of times per second. A TPU has no efficient scatter, so the
histogram fill is re-thought for the MXU (DESIGN.md Hardware-Adaptation):
each value is mapped to a bin index, the indices are expanded to a one-hot
matrix against a broadcasted iota, and the bin counts are the column sums —
a [block, slots] reduction the systolic array handles natively.

Slot convention: 0 = underflow, 1..NBINS = in-range, NBINS+1 = overflow.
Masked-out lanes are parked in a dead slot so they never contribute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .shapes import NBINS


def _bin_indices(values, mask, lo, hi, nbins):
    """Map values to histogram slots [0, nbins+1]; masked lanes -> -1."""
    width = (hi - lo) / nbins
    raw = jnp.floor((values - lo) / width)
    idx = jnp.clip(raw, -1.0, float(nbins)).astype(jnp.int32) + 1  # 0..nbins+1
    # NaNs compare false everywhere; route them (and masked lanes) to -1,
    # which matches no one-hot column.
    idx = jnp.where(jnp.isnan(values), -1, idx)
    return jnp.where(mask, idx, -1)


#: Histogram binning strategy:
#:   "scatter" (default) — scatter-add into the bin vector: O(M) work, the
#:       fast path for the CPU-PJRT artifacts this repo executes;
#:   "onehot"  — one-hot matrix against a broadcasted iota contracted over
#:       the block: O(M x slots) scalar work but a single dense [M, slots]
#:       reduction the TPU MXU executes natively (scatter is the op TPUs
#:       lack). Select with HEPQ_HIST_MODE when baking artifacts.
#: Perf note (EXPERIMENTS.md §Perf): switching the CPU artifacts from
#: onehot to scatter sped the pair-query kernels up by ~40x end to end.
import os

HIST_MODE = os.environ.get("HEPQ_HIST_MODE", "scatter")


def _hist_block(values, mask, lo, hi, nbins):
    """Histogram a flat block of values into [nbins+2] counts."""
    idx = _bin_indices(values, mask, lo, hi, nbins)
    if HIST_MODE == "onehot":
        slots = jax.lax.broadcasted_iota(jnp.int32, (values.shape[0], nbins + 2), 1)
        onehot = (idx[:, None] == slots).astype(jnp.float32)
        return jnp.sum(onehot, axis=0)
    # Scatter mode: park invalid lanes (-1) in a dead slot past the end and
    # drop it after the scatter-add.
    idx = jnp.where(idx < 0, nbins + 2, idx)
    hist = jnp.zeros(nbins + 3, dtype=jnp.float32).at[idx].add(1.0)
    return hist[: nbins + 2]


def _fill_kernel(v_ref, m_ref, lo_ref, hi_ref, o_ref, *, nbins):
    """Grid step: accumulate this block's partial histogram."""
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    block = _hist_block(
        v_ref[...], m_ref[...] != 0, lo_ref[0], hi_ref[0], nbins
    )
    o_ref[...] += block


@functools.partial(jax.jit, static_argnames=("block", "nbins"))
def hist_fill(values, mask, lo, hi, *, block=4096, nbins=NBINS):
    """Histogram a flat f32 vector under an i32 validity mask.

    values: f32[M] (M must be a multiple of `block`)
    mask:   i32[M] (nonzero = valid)
    lo/hi:  f32[1] binning range
    returns f32[nbins+2] = [underflow, bins..., overflow]
    """
    (m,) = values.shape
    assert m % block == 0, f"M={m} not a multiple of block={block}"
    grid = m // block
    return pl.pallas_call(
        functools.partial(_fill_kernel, nbins=nbins),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((nbins + 2,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((nbins + 2,), jnp.float32),
        interpret=True,
    )(values, mask, lo, hi)
