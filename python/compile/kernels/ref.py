"""Pure-numpy oracle for every kernel and query — the CORE correctness
signal.

Implemented exactly as the paper's Table-3 pseudocode: explicit Python
loops over events and muons, no vectorization, no clever indexing. If a
Pallas kernel and this file agree across the hypothesis sweep, the kernel
is right.

Histogram slot convention matches the kernels:
[underflow, bins..., overflow] → NBINS + 2 slots; values with x == hi go to
overflow (right-open bins); NaN is dropped.
"""

import math

import numpy as np

from .shapes import NBINS


def hist_slots(values, lo, hi, nbins=NBINS):
    """Histogram a python iterable into [under, bins..., over]."""
    out = np.zeros(nbins + 2, dtype=np.float64)
    width = (hi - lo) / nbins
    for v in values:
        v = float(v)
        if math.isnan(v):
            continue
        if v < lo:
            out[0] += 1.0
        else:
            i = int(math.floor((v - lo) / width))
            if i < nbins:
                out[1 + i] += 1.0
            else:
                out[nbins + 1] += 1.0
    return out


def events_from_offsets(offsets, *arrays):
    """Yield per-event lists of attribute tuples from exploded arrays."""
    for i in range(len(offsets) - 1):
        lo, hi = int(offsets[i]), int(offsets[i + 1])
        yield [tuple(float(a[k]) for a in arrays) for k in range(lo, hi)]


# ---------------------------------------------------------------- Table 3

def max_pt(offsets, pt, lo, hi, nbins=NBINS):
    """for event: maximum = -inf; for muon: if pt > max ...; fill(max)
    (fills only when the event has at least one muon)."""
    vals = []
    for muons in events_from_offsets(offsets, pt):
        if not muons:
            continue
        maximum = -float("inf")
        for (mpt,) in muons:
            if mpt > maximum:
                maximum = mpt
        vals.append(maximum)
    return hist_slots(vals, lo, hi, nbins)


def eta_best(offsets, pt, eta, lo, hi, nbins=NBINS):
    """eta of the highest-pt muon per event (first wins on ties)."""
    vals = []
    for muons in events_from_offsets(offsets, pt, eta):
        maximum = -float("inf")
        best = None
        for (mpt, meta) in muons:
            if mpt > maximum:
                maximum = mpt
                best = meta
        if best is not None:
            vals.append(best)
    return hist_slots(vals, lo, hi, nbins)


def ptsum_pairs(offsets, pt, lo, hi, nbins=NBINS):
    """pt_i + pt_j over distinct pairs i < j."""
    vals = []
    for muons in events_from_offsets(offsets, pt):
        n = len(muons)
        for i in range(n):
            for j in range(i + 1, n):
                vals.append(muons[i][0] + muons[j][0])
    return hist_slots(vals, lo, hi, nbins)


def mass_pairs(offsets, pt, eta, phi, lo, hi, nbins=NBINS):
    """sqrt(2 pt_i pt_j (cosh(deta) - cos(dphi))) over distinct pairs."""
    vals = []
    for muons in events_from_offsets(offsets, pt, eta, phi):
        n = len(muons)
        for i in range(n):
            for j in range(i + 1, n):
                p1, e1, f1 = muons[i]
                p2, e2, f2 = muons[j]
                m2 = 2.0 * p1 * p2 * (math.cosh(e1 - e2) - math.cos(f1 - f2))
                vals.append(math.sqrt(max(m2, 0.0)))
    return hist_slots(vals, lo, hi, nbins)


def jetpt_hist(offsets, pt, lo, hi, nbins=NBINS):
    """Table 1's payload: histogram every jet pt."""
    vals = []
    for jets in events_from_offsets(offsets, pt):
        for (jpt,) in jets:
            vals.append(jpt)
    return hist_slots(vals, lo, hi, nbins)


# ------------------------------------------------------------- pad helpers

def pad_from_offsets(offsets, content, n_events, k_max, fill=0.0):
    """Reference implementation of the L2 gather/pad: exploded -> [N, K]
    padded matrix + i32 mask. Events beyond len(offsets)-1 are empty.
    Lists longer than k_max are truncated (the coordinator guarantees the
    generators respect k_max, but the kernel contract is explicit)."""
    out = np.full((n_events, k_max), fill, dtype=np.float32)
    mask = np.zeros((n_events, k_max), dtype=np.int32)
    for i in range(min(n_events, len(offsets) - 1)):
        lo, hi = int(offsets[i]), int(offsets[i + 1])
        n = min(hi - lo, k_max)
        out[i, :n] = content[lo : lo + n]
        mask[i, :n] = 1
    return out, mask


def truncate_offsets(offsets, k_max):
    """Per-event lengths clamped to k_max (what the padded view computes)."""
    off = np.asarray(offsets, dtype=np.int64)
    counts = np.minimum(off[1:] - off[:-1], k_max)
    out = np.zeros(len(off), dtype=np.int64)
    out[1:] = np.cumsum(counts)
    return out
