"""AOT export: lower every L2 query graph to HLO text + a manifest.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the `xla` rust crate) rejects; the text parser
reassigns ids and round-trips cleanly.

Usage (from python/):  python -m compile.aot --out-dir ../artifacts
Produces:
    artifacts/q_<name>.hlo.txt     one module per query
    artifacts/manifest.json        shapes + input layout for the Rust runtime
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model
from .kernels.shapes import DEFAULT_SPEC, NBINS, PartitionSpec


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see /opt/xla-example)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_query(name: str, spec: PartitionSpec):
    factory, n_content = model.QUERIES[name]
    fn = factory(spec)
    args = model.example_args(spec, n_content)
    return jax.jit(fn).lower(*args), n_content


def export_all(out_dir: str, spec: PartitionSpec) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {
        "version": 1,
        "partition": {
            "n_events": spec.n_events,
            "k_max": spec.k_max,
            "content_cap": spec.content_cap,
            "n_offsets": spec.n_offsets,
        },
        "nbins": NBINS,
        "hist_slots": NBINS + 2,
        "queries": {},
    }
    for name in model.QUERIES:
        lowered, n_content = lower_query(name, spec)
        text = to_hlo_text(lowered)
        fname = f"q_{name}.hlo.txt"
        path = os.path.join(out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        manifest["queries"][name] = {
            "file": fname,
            "n_content_arrays": n_content,
            "inputs": ["offsets_i32"]
            + [f"content_f32_{i}" for i in range(n_content)]
            + ["lo_f32", "hi_f32"],
            "output": "hist_f32_slots",
        }
        print(f"wrote {path} ({len(text)} chars)")
    mpath = os.path.join(out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {mpath}")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--events", type=int, default=DEFAULT_SPEC.n_events,
        help="events per partition baked into the artifacts",
    )
    ap.add_argument("--kmax", type=int, default=DEFAULT_SPEC.k_max)
    args = ap.parse_args()
    spec = PartitionSpec(
        n_events=args.events,
        k_max=args.kmax,
        content_cap=8 * args.events,
        block_events=min(DEFAULT_SPEC.block_events, args.events),
    )
    export_all(args.out_dir, spec)


if __name__ == "__main__":
    main()
