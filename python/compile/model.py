"""Layer-2 JAX query graphs.

Each query program is one jitted function over a *padded partition*:

    (offsets i32[N+1], content arrays f32[C], lo f32[1], hi f32[1])
        -> (hist f32[NBINS+2],)

The graph has two stages, fused by XLA into a single module:

1. **Regularize** — turn the exploded offsets+content representation into
   padded [N, K] tiles with a validity mask, using a clamped gather
   (`offsets[i] + k`). No event objects are ever materialized: this is the
   columnar-to-columnar reshaping the paper performs implicitly when it
   vectorizes transformed loops.
2. **Compute+fill** — call the L1 Pallas kernel, which fuses the Table-3
   physics computation with the histogram fill.

Python (this file) runs only at build time: `aot.py` lowers these functions
to HLO text, and the Rust coordinator executes the artifacts via PJRT.
"""

import jax
import jax.numpy as jnp

from .kernels import event, hist, pairs
from .kernels.shapes import NBINS, PartitionSpec


def pad_partition(offsets, content, n_events, k_max):
    """Exploded (offsets, content) -> padded [N, K] values + i32 mask.

    offsets: i32[N+1] (monotone, offsets[0] == 0, offsets may imply more
             than K items per event — extra items are truncated, matching
             `ref.pad_from_offsets`).
    content: f32[C]   (C >= offsets[-1])
    """
    counts = jnp.minimum(offsets[1:] - offsets[:-1], k_max)       # [N]
    k = jax.lax.broadcasted_iota(jnp.int32, (n_events, k_max), 1)  # [N, K]
    idx = offsets[:-1, None] + k                                   # [N, K]
    mask = (k < counts[:, None]).astype(jnp.int32)
    idx = jnp.clip(idx, 0, content.shape[0] - 1)
    vals = content[idx]                                            # gather
    vals = jnp.where(mask != 0, vals, 0.0)
    return vals, mask


def _block(spec: PartitionSpec) -> int:
    return min(spec.block_events, spec.n_events)


def q_max_pt(spec: PartitionSpec):
    """Query: histogram of per-event max muon pt."""

    def fn(offsets, pt, lo, hi):
        vals, mask = pad_partition(offsets, pt, spec.n_events, spec.k_max)
        return (event.max_pt_hist(vals, mask, lo, hi, block=_block(spec)),)

    return fn


def q_eta_best(spec: PartitionSpec):
    """Query: histogram of eta of the highest-pt muon per event."""

    def fn(offsets, pt, eta, lo, hi):
        p, mask = pad_partition(offsets, pt, spec.n_events, spec.k_max)
        e, _ = pad_partition(offsets, eta, spec.n_events, spec.k_max)
        return (event.eta_best_hist(p, e, mask, lo, hi, block=_block(spec)),)

    return fn


def q_ptsum_pairs(spec: PartitionSpec):
    """Query: histogram of pt_i + pt_j over distinct muon pairs."""

    def fn(offsets, pt, lo, hi):
        p, mask = pad_partition(offsets, pt, spec.n_events, spec.k_max)
        return (pairs.ptsum_pairs_hist(p, mask, lo, hi, block=_block(spec)),)

    return fn


def q_mass_pairs(spec: PartitionSpec):
    """Query: histogram of dimuon invariant mass over distinct pairs."""

    def fn(offsets, pt, eta, phi, lo, hi):
        p, mask = pad_partition(offsets, pt, spec.n_events, spec.k_max)
        e, _ = pad_partition(offsets, eta, spec.n_events, spec.k_max)
        f, _ = pad_partition(offsets, phi, spec.n_events, spec.k_max)
        return (pairs.mass_pairs_hist(p, e, f, mask, lo, hi, block=_block(spec)),)

    return fn


def q_flat_hist(spec: PartitionSpec):
    """Query: histogram of every item of one content array (Table 1's
    jet-pt fill). Works directly on the flat content array: the validity
    mask is `position < offsets[-1]`, no padding needed."""

    def fn(offsets, pt, lo, hi):
        total = offsets[-1]
        pos = jax.lax.iota(jnp.int32, pt.shape[0])
        mask = (pos < total).astype(jnp.int32)
        return (hist.hist_fill(pt, mask, lo, hi, block=_flat_block(spec)),)

    return fn


def _flat_block(spec: PartitionSpec) -> int:
    return min(4096, spec.content_cap)


#: name -> (factory, content-argument count (excluding offsets/lo/hi))
QUERIES = {
    "max_pt": (q_max_pt, 1),
    "eta_best": (q_eta_best, 2),
    "ptsum_pairs": (q_ptsum_pairs, 1),
    "mass_pairs": (q_mass_pairs, 3),
    "flat_hist": (q_flat_hist, 1),
}


def example_args(spec: PartitionSpec, n_content_arrays: int):
    """ShapeDtypeStructs for lowering a query with the given arity."""
    off = jax.ShapeDtypeStruct((spec.n_offsets,), jnp.int32)
    content = [
        jax.ShapeDtypeStruct((spec.content_cap,), jnp.float32)
        for _ in range(n_content_arrays)
    ]
    scalar = jax.ShapeDtypeStruct((1,), jnp.float32)
    return [off, *content, scalar, scalar]
