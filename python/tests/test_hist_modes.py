"""Both histogram-fill strategies (scatter for CPU artifacts, one-hot for
the TPU MXU path) must agree with the oracle and with each other."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import hist, ref
from compile.kernels.shapes import NBINS


def run_mode(mode, values, mask, lo, hi, block):
    old = hist.HIST_MODE
    hist.HIST_MODE = mode
    try:
        # New jit cache key per mode is not automatic (mode is read inside
        # the kernel at trace time), so bypass the cached jit wrapper.
        fn = hist.hist_fill.__wrapped__
        return np.asarray(fn(values, mask, lo, hi, block=block, nbins=NBINS))
    finally:
        hist.HIST_MODE = old


class TestHistModes:
    @settings(max_examples=20, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        n=st.sampled_from([16, 64, 128]),
        lo=st.floats(-50.0, 0.0),
        width=st.floats(1.0, 200.0),
    )
    def test_modes_agree_with_oracle(self, seed, n, lo, width):
        rng = np.random.default_rng(seed)
        values = rng.uniform(lo - 20, lo + width + 20, n).astype(np.float32)
        mask = (rng.random(n) < 0.8).astype(np.int32)
        slo = np.array([lo], np.float32)
        shi = np.array([lo + width], np.float32)
        expect = ref.hist_slots(values[mask == 1], np.float32(lo),
                                np.float32(lo + width))
        for mode in ("scatter", "onehot"):
            out = run_mode(mode, values, mask, slo, shi, block=n // 2)
            np.testing.assert_allclose(out, expect, err_msg=mode)

    def test_nan_dropped_in_both_modes(self):
        values = np.array([np.nan, 1.0, np.nan, 2.0], np.float32)
        mask = np.ones(4, np.int32)
        lo = np.array([0.0], np.float32)
        hi = np.array([8.0], np.float32)
        for mode in ("scatter", "onehot"):
            out = run_mode(mode, values, mask, lo, hi, block=4)
            assert out.sum() == 2.0, mode
