"""L2 query graphs vs the oracle: the whole padded-partition pipeline
(offsets gather + kernel + histogram), plus AOT lowering smoke tests."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref
from compile.kernels.shapes import NBINS, PartitionSpec

SPEC = PartitionSpec(n_events=32, k_max=4, content_cap=256, block_events=8)


def make_partition(rng, spec, n_live=None):
    """Random padded partition in the runtime's wire layout."""
    n_live = spec.n_events if n_live is None else n_live
    counts = rng.integers(0, spec.k_max + 1, size=n_live)
    offsets = np.zeros(spec.n_offsets, dtype=np.int32)
    offsets[1 : n_live + 1] = np.cumsum(counts)
    offsets[n_live + 1 :] = offsets[n_live]  # padding events are empty
    total = int(offsets[-1])
    def content():
        arr = np.zeros(spec.content_cap, dtype=np.float32)
        arr[:total] = rng.uniform(0.5, 120.0, size=total)
        return arr
    return offsets, content(), content(), content()


def scalars(lo, hi):
    return np.array([lo], np.float32), np.array([hi], np.float32)


class TestQueriesAgainstOracle:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), n_live=st.sampled_from([0, 7, 32]))
    def test_max_pt(self, seed, n_live):
        rng = np.random.default_rng(seed)
        offsets, pt, _, _ = make_partition(rng, SPEC, n_live)
        lo, hi = scalars(0.0, 128.0)
        (out,) = model.q_max_pt(SPEC)(offsets, pt, lo, hi)
        np.testing.assert_allclose(
            np.asarray(out), ref.max_pt(offsets, pt, 0.0, 128.0)
        )

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_eta_best(self, seed):
        rng = np.random.default_rng(seed)
        offsets, pt, eta, _ = make_partition(rng, SPEC)
        eta = (eta % 4.8) - 2.4
        lo, hi = scalars(-2.4, 2.4)
        (out,) = model.q_eta_best(SPEC)(offsets, pt, eta, lo, hi)
        np.testing.assert_allclose(
            np.asarray(out),
            ref.eta_best(offsets, pt, eta, np.float32(-2.4), np.float32(2.4)),
        )

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_ptsum_pairs(self, seed):
        rng = np.random.default_rng(seed)
        offsets, pt, _, _ = make_partition(rng, SPEC)
        lo, hi = scalars(0.0, 256.0)
        (out,) = model.q_ptsum_pairs(SPEC)(offsets, pt, lo, hi)
        np.testing.assert_allclose(
            np.asarray(out), ref.ptsum_pairs(offsets, pt, 0.0, 256.0)
        )

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_mass_pairs(self, seed):
        rng = np.random.default_rng(seed)
        offsets, pt, eta, phi = make_partition(rng, SPEC)
        eta = (eta % 4.8) - 2.4
        phi = (phi % (2 * np.pi)) - np.pi
        lo, hi = scalars(0.0, 200.0)
        (out,) = model.q_mass_pairs(SPEC)(offsets, pt, eta, phi, lo, hi)
        expect = ref.mass_pairs(offsets, pt, eta, phi, 0.0, 200.0)
        out = np.asarray(out)
        assert out.sum() == expect.sum()
        assert np.abs(out - expect).sum() <= 4.0

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_flat_hist(self, seed):
        rng = np.random.default_rng(seed)
        offsets, pt, _, _ = make_partition(rng, SPEC)
        lo, hi = scalars(0.0, 128.0)
        (out,) = model.q_flat_hist(SPEC)(offsets, pt, lo, hi)
        np.testing.assert_allclose(
            np.asarray(out), ref.jetpt_hist(offsets, pt, 0.0, 128.0)
        )

    def test_empty_partition(self):
        offsets = np.zeros(SPEC.n_offsets, dtype=np.int32)
        pt = np.zeros(SPEC.content_cap, dtype=np.float32)
        lo, hi = scalars(0.0, 64.0)
        for q in [model.q_max_pt(SPEC), model.q_ptsum_pairs(SPEC),
                  model.q_flat_hist(SPEC)]:
            (out,) = q(offsets, pt, lo, hi)
            assert np.asarray(out).sum() == 0.0


class TestPadPartition:
    def test_matches_reference(self):
        rng = np.random.default_rng(3)
        offsets, pt, _, _ = make_partition(rng, SPEC)
        got_v, got_m = model.pad_partition(offsets, pt, SPEC.n_events, SPEC.k_max)
        want_v, want_m = ref.pad_from_offsets(offsets, pt, SPEC.n_events, SPEC.k_max)
        np.testing.assert_allclose(np.asarray(got_v), want_v)
        np.testing.assert_array_equal(np.asarray(got_m), want_m)


class TestAotLowering:
    def test_all_queries_lower_to_hlo_text(self, tmp_path):
        from compile import aot

        spec = PartitionSpec(n_events=16, k_max=4, content_cap=128,
                             block_events=8)
        manifest = aot.export_all(str(tmp_path), spec)
        assert set(manifest["queries"]) == set(model.QUERIES)
        for q in manifest["queries"].values():
            text = (tmp_path / q["file"]).read_text()
            assert "HloModule" in text
        assert (tmp_path / "manifest.json").exists()
