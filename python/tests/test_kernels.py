"""Kernel-vs-oracle correctness: every L1 Pallas kernel against the pure
numpy/python-loop reference, over hand-picked cases and hypothesis sweeps
of shapes, multiplicities and value ranges."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import event, hist, pairs, ref
from compile.kernels.shapes import NBINS


def make_exploded(rng, n_events, k_max, lo=-50.0, hi=150.0):
    """Random exploded arrays with multiplicities in [0, k_max]."""
    counts = rng.integers(0, k_max + 1, size=n_events)
    offsets = np.zeros(n_events + 1, dtype=np.int32)
    offsets[1:] = np.cumsum(counts)
    total = int(offsets[-1])
    pt = rng.uniform(0.5, 120.0, size=total).astype(np.float32)
    eta = rng.uniform(-2.4, 2.4, size=total).astype(np.float32)
    phi = rng.uniform(-np.pi, np.pi, size=total).astype(np.float32)
    return offsets, pt, eta, phi


def pad(offsets, content, n_events, k):
    return ref.pad_from_offsets(offsets, content, n_events, k)


def as_scalar_arrays(lo, hi):
    return np.array([lo], np.float32), np.array([hi], np.float32)


# ------------------------------------------------------------- hist_fill

class TestHistFill:
    def test_basic_binning(self):
        values = np.array([0.5, 1.5, 1.6, 63.9, -1.0, 64.0, 200.0, 5.0],
                          np.float32)
        mask = np.ones(8, np.int32)
        lo, hi = as_scalar_arrays(0.0, 64.0)
        out = np.asarray(hist.hist_fill(values, mask, lo, hi, block=8))
        expect = ref.hist_slots(values, 0.0, 64.0)
        np.testing.assert_allclose(out, expect)
        assert out[0] == 1.0      # underflow
        assert out[NBINS + 1] == 2.0  # 64.0 and 200.0 overflow

    def test_mask_excludes(self):
        values = np.array([1.0, 2.0, 3.0, 4.0], np.float32)
        mask = np.array([1, 0, 1, 0], np.int32)
        lo, hi = as_scalar_arrays(0.0, 8.0)
        out = np.asarray(hist.hist_fill(values, mask, lo, hi, block=4))
        assert out.sum() == 2.0

    def test_nan_dropped(self):
        values = np.array([np.nan, 1.0, np.nan, 2.0], np.float32)
        mask = np.ones(4, np.int32)
        lo, hi = as_scalar_arrays(0.0, 8.0)
        out = np.asarray(hist.hist_fill(values, mask, lo, hi, block=4))
        assert out.sum() == 2.0

    def test_multi_block_accumulation(self):
        rng = np.random.default_rng(0)
        values = rng.uniform(-10, 110, size=64).astype(np.float32)
        mask = (rng.random(64) < 0.7).astype(np.int32)
        lo, hi = as_scalar_arrays(0.0, 100.0)
        out = np.asarray(hist.hist_fill(values, mask, lo, hi, block=16))
        expect = ref.hist_slots(values[mask == 1], 0.0, 100.0)
        np.testing.assert_allclose(out, expect)

    @settings(max_examples=25, deadline=None)
    @given(
        n=st.sampled_from([16, 32, 64, 128]),
        seed=st.integers(0, 2**31 - 1),
        lo=st.floats(-100.0, 0.0),
        width=st.floats(1.0, 300.0),
    )
    def test_hypothesis_sweep(self, n, seed, lo, width):
        rng = np.random.default_rng(seed)
        values = rng.uniform(lo - 50, lo + width + 50, n).astype(np.float32)
        mask = (rng.random(n) < 0.8).astype(np.int32)
        slo, shi = as_scalar_arrays(lo, lo + width)
        out = np.asarray(hist.hist_fill(values, mask, slo, shi, block=n // 2))
        expect = ref.hist_slots(values[mask == 1], np.float32(lo),
                                np.float32(lo + width))
        np.testing.assert_allclose(out, expect)


# ----------------------------------------------------------- event kernels

class TestMaxPt:
    def test_simple(self):
        offsets = np.array([0, 2, 2, 5], np.int32)
        pt = np.array([10.0, 30.0, 7.0, 5.0, 9.0], np.float32)
        p, m = pad(offsets, pt, 4, 4)
        lo, hi = as_scalar_arrays(0.0, 64.0)
        out = np.asarray(event.max_pt_hist(p, m, lo, hi, block=4))
        expect = ref.max_pt(offsets, pt, 0.0, 64.0)
        np.testing.assert_allclose(out, expect)
        assert out.sum() == 2.0  # empty event contributes nothing

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), n=st.sampled_from([8, 32, 64]))
    def test_hypothesis_sweep(self, seed, n):
        rng = np.random.default_rng(seed)
        offsets, pt, _, _ = make_exploded(rng, n, 6)
        p, m = pad(offsets, pt, n, 6)
        lo, hi = as_scalar_arrays(0.0, 128.0)
        out = np.asarray(event.max_pt_hist(p, m, lo, hi, block=n // 2))
        np.testing.assert_allclose(out, ref.max_pt(offsets, pt, 0.0, 128.0))


class TestEtaBest:
    def test_tie_takes_first(self):
        offsets = np.array([0, 2], np.int32)
        pt = np.array([30.0, 30.0], np.float32)
        eta = np.array([1.0, -1.0], np.float32)
        p, m = pad(offsets, pt, 1, 2)
        e, _ = pad(offsets, eta, 1, 2)
        lo, hi = as_scalar_arrays(-2.4, 2.4)
        out = np.asarray(event.eta_best_hist(p, e, m, lo, hi, block=1))
        expect = ref.eta_best(offsets, pt, eta, -2.4, 2.4)
        np.testing.assert_allclose(out, expect)

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), n=st.sampled_from([8, 32]))
    def test_hypothesis_sweep(self, seed, n):
        rng = np.random.default_rng(seed)
        offsets, pt, eta, _ = make_exploded(rng, n, 5)
        p, m = pad(offsets, pt, n, 5)
        e, _ = pad(offsets, eta, n, 5)
        lo, hi = as_scalar_arrays(-2.4, 2.4)
        out = np.asarray(event.eta_best_hist(p, e, m, lo, hi, block=n // 2))
        np.testing.assert_allclose(
            out, ref.eta_best(offsets, pt, eta, np.float32(-2.4), np.float32(2.4))
        )


# ------------------------------------------------------------ pair kernels

class TestPtSumPairs:
    def test_three_muons_three_pairs(self):
        offsets = np.array([0, 3], np.int32)
        pt = np.array([10.0, 20.0, 30.0], np.float32)
        p, m = pad(offsets, pt, 1, 4)
        lo, hi = as_scalar_arrays(0.0, 64.0)
        out = np.asarray(pairs.ptsum_pairs_hist(p, m, lo, hi, block=1))
        expect = ref.ptsum_pairs(offsets, pt, 0.0, 64.0)
        np.testing.assert_allclose(out, expect)
        assert out.sum() == 3.0

    def test_zero_and_one_muon_no_pairs(self):
        offsets = np.array([0, 0, 1], np.int32)
        pt = np.array([50.0], np.float32)
        p, m = pad(offsets, pt, 2, 4)
        lo, hi = as_scalar_arrays(0.0, 64.0)
        out = np.asarray(pairs.ptsum_pairs_hist(p, m, lo, hi, block=2))
        assert out.sum() == 0.0

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), n=st.sampled_from([8, 32]))
    def test_hypothesis_sweep(self, seed, n):
        rng = np.random.default_rng(seed)
        offsets, pt, _, _ = make_exploded(rng, n, 6)
        p, m = pad(offsets, pt, n, 6)
        lo, hi = as_scalar_arrays(0.0, 256.0)
        out = np.asarray(pairs.ptsum_pairs_hist(p, m, lo, hi, block=n // 2))
        np.testing.assert_allclose(out, ref.ptsum_pairs(offsets, pt, 0.0, 256.0))


class TestMassPairs:
    def test_back_to_back_is_z_like(self):
        # Two muons, pt 45.6 each, opposite phi, same eta:
        # m = sqrt(2*45.6*45.6*(1 - cos(pi))) = 91.2
        offsets = np.array([0, 2], np.int32)
        pt = np.array([45.6, 45.6], np.float32)
        eta = np.array([0.0, 0.0], np.float32)
        phi = np.array([0.0, np.pi], np.float32)
        p, m = pad(offsets, pt, 1, 2)
        e, _ = pad(offsets, eta, 1, 2)
        f, _ = pad(offsets, phi, 1, 2)
        lo, hi = as_scalar_arrays(0.0, 128.0)
        out = np.asarray(pairs.mass_pairs_hist(p, e, f, m, lo, hi, block=1))
        # 91.2 lands in bin floor(91.2/2) = 45 → slot 46
        assert out[46] == 1.0
        assert out.sum() == 1.0

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), n=st.sampled_from([8, 16]))
    def test_hypothesis_sweep(self, seed, n):
        rng = np.random.default_rng(seed)
        offsets, pt, eta, phi = make_exploded(rng, n, 5)
        p, m = pad(offsets, pt, n, 5)
        e, _ = pad(offsets, eta, n, 5)
        f, _ = pad(offsets, phi, n, 5)
        lo, hi = as_scalar_arrays(0.0, 200.0)
        out = np.asarray(pairs.mass_pairs_hist(p, e, f, m, lo, hi, block=n // 2))
        expect = ref.mass_pairs(offsets, pt, eta, phi, 0.0, 200.0)
        # f32 cosh/cos vs f64 math: values landing exactly on a bin edge can
        # differ by one bin; compare totals exactly and bins loosely.
        assert out.sum() == expect.sum()
        # At most a couple of edge migrations allowed.
        assert np.abs(out - expect).sum() <= 4.0


# -------------------------------------------------------------- pad helper

class TestPadFromOffsets:
    def test_truncates_long_lists(self):
        offsets = np.array([0, 6], np.int32)
        content = np.arange(6, dtype=np.float32)
        out, mask = ref.pad_from_offsets(offsets, content, 1, 4)
        assert mask.sum() == 4
        np.testing.assert_allclose(out[0], [0, 1, 2, 3])

    def test_pads_missing_events(self):
        offsets = np.array([0, 1], np.int32)
        content = np.array([5.0], np.float32)
        out, mask = ref.pad_from_offsets(offsets, content, 3, 2)
        assert mask.sum() == 1
        assert out.shape == (3, 2)
