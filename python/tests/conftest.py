"""Test-suite bootstrap: make the `compile` package importable from a repo
checkout, and skip the suite cleanly where the optional heavy deps (jax,
hypothesis) are not installed — CI installs them; minimal dev containers may
not."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

collect_ignore_glob = []
try:
    import hypothesis  # noqa: F401
    import jax  # noqa: F401
except ImportError:
    collect_ignore_glob = ["test_*.py"]
